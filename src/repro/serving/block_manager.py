"""Paged KV-cache block accounting for the serving engine.

The KV pool is a fixed set of fixed-size blocks (``block_size`` token
positions each, all layers ride together in the model-side pool arrays);
a lane's cache is the ordered list of blocks in its block table, so
admission capacity is bound by *live tokens*, not by lanes times the
worst-case sequence length.

Block id 0 is RESERVED as the sink: free decode lanes and right-pad
positions scatter their garbage writes there, so the manager hands out
ids ``1..n_blocks`` only.

Watermark: ``can_admit`` keeps ``watermark_blocks`` free blocks in
reserve for decode-time growth of already-running lanes — admitting up
to the last block converts every subsequent grow into a preemption.
Growth allocation (``allocate_one``) ignores the watermark; running
requests always get priority over queued ones.

Prefix caching (copy-on-write sharing)
--------------------------------------

Every block is REFCOUNTED.  A full block whose token content is known can
be *registered* in a content-addressed cache keyed by the chained digest
of everything up to and including the block (position matters: the same
16 tokens after a different prefix hold different K/V).  A later request
whose prompt starts with the same token prefix *matches* those blocks and
shares them (`ref`) instead of allocating + recomputing:

* blocks shared by live lanes carry ``ref_count >= 2`` and are immutable;
  a lane whose next write lands inside a shared block must `cow_split`
  first (the engine copies the device content old -> new).
* a released block whose refcount reaches zero stays CACHED but joins the
  free pool; allocation prefers never-cached blocks and only then evicts
  cached ones, least recently used first, so idle cache survives as long
  as memory pressure allows.
* a sole-holder (``ref_count == 1``) cached block about to be written
  diverges from its registered content and must be `uncache`d instead of
  split — reuse without a copy.

``match_prefix`` is a pure query (no refcounts taken); admission decides
what it can afford, then takes hits with `ref` BEFORE allocating fresh
blocks, so the allocator cannot evict the very blocks being matched.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Set, Tuple


def _chain_key(parent: Optional[bytes], chunk: Sequence[int]) -> bytes:
    """Digest of a full block's content, chained through its prefix."""
    h = hashlib.blake2b(digest_size=16)
    h.update(parent or b"\x00")
    h.update(b",".join(str(int(t)).encode() for t in chunk))
    return h.digest()


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Result of a prefix-cache lookup (pure; nothing is reserved)."""

    blocks: Tuple[int, ...]  # cached blocks covering the prefix, in order
    n_tokens: int  # token positions covered (last block may be partial)
    tail_partial: bool  # last matched block is only prefix-matched


class BlockManager:
    """Refcounting free-list allocator over ``n_blocks`` usable KV blocks."""

    def __init__(self, n_blocks: int, block_size: int, watermark_frac: float = 0.0):
        if n_blocks < 1:
            raise ValueError(f"need at least one usable block, got {n_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if not 0.0 <= watermark_frac < 1.0:
            raise ValueError(f"watermark_frac must be in [0, 1), got {watermark_frac}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.watermark_blocks = int(watermark_frac * n_blocks)
        # LIFO free list of never-cached blocks: recently freed reused first
        self._free_plain: List[int] = list(range(n_blocks, 0, -1))
        # refcount == 0 but content still registered; OrderedDict as an LRU
        # (oldest first) so eviction keeps the hottest cache entries alive
        self._free_cached: "OrderedDict[int, None]" = OrderedDict()
        self._ref: Dict[int, int] = {}  # allocated block -> refcount
        # content cache: block -> (chain key, tokens); inverse + parent index
        self._key_of: Dict[int, bytes] = {}
        self._tokens_of: Dict[int, Tuple[int, ...]] = {}
        self._parent_of: Dict[int, Optional[bytes]] = {}
        self._by_key: Dict[bytes, int] = {}
        self._by_parent: Dict[Optional[bytes], Set[int]] = {}
        self.peak_in_use = 0
        self.alloc_count = 0
        self.free_count = 0
        # prefix-cache / sharing gauges
        self.shared_now = 0  # blocks with ref_count >= 2
        self.shared_peak = 0
        self.cow_splits = 0
        self.evictions = 0
        # bumped on every mutation that can change a prefix-match or a
        # refcount — lets callers memoize match-derived quantities (e.g.
        # admission footprints) instead of re-hashing prompts every step
        self.version = 0

    # ------------------------------------------------------------------
    @property
    def free(self) -> int:
        return len(self._free_plain) + len(self._free_cached)

    @property
    def in_use(self) -> int:
        return self.n_blocks - self.free

    @property
    def utilization(self) -> float:
        return self.in_use / self.n_blocks

    @property
    def cached_blocks(self) -> int:
        return len(self._key_of)

    def blocks_needed(self, n_tokens: int) -> int:
        """Blocks covering ``n_tokens`` cache positions (at least one)."""
        return max(1, -(-n_tokens // self.block_size))

    def can_admit(self, n: int) -> bool:
        """Whether ``n`` blocks may go to a NEW request (watermark applies).

        ``n`` must count every free block the admission will consume: fresh
        allocations AND refcount-zero cache hits it revives.
        """
        return self.free - n >= self.watermark_blocks

    def ref_count(self, block: int) -> int:
        return self._ref.get(block, 0)

    def is_cached(self, block: int) -> bool:
        return block in self._key_of

    # ------------------------------------------------------------------
    # allocation / refcounting
    # ------------------------------------------------------------------
    def _track_shared(self, before: int, after: int) -> None:
        if before < 2 <= after:
            self.shared_now += 1
            self.shared_peak = max(self.shared_peak, self.shared_now)
        elif after < 2 <= before:
            self.shared_now -= 1

    def allocate(self, n: int) -> Optional[List[int]]:
        """Take ``n`` fresh blocks (no watermark), or None without side
        effects.  Prefers never-cached blocks; evicts cached free blocks
        (LRU) only when it must, dropping their registrations."""
        if n > self.free:
            return None
        taken: List[int] = []
        for _ in range(n):
            if self._free_plain:
                b = self._free_plain.pop()
            else:
                b, _ = self._free_cached.popitem(last=False)  # LRU
                self._forget(b)
                self.evictions += 1
            self._ref[b] = 1
            taken.append(b)
        self.alloc_count += n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return taken

    def allocate_one(self) -> Optional[int]:
        got = self.allocate(1)
        return got[0] if got else None

    def ref(self, block: int) -> None:
        """Take a share of a block: a live one (refcount += 1) or a cached
        free one (revived out of the free pool at refcount 1)."""
        self.version += 1
        rc = self._ref.get(block)
        if rc is not None:
            self._ref[block] = rc + 1
            self._track_shared(rc, rc + 1)
            return
        if block not in self._free_cached:
            raise ValueError(f"block {block} is neither live nor cached-free")
        del self._free_cached[block]
        self._ref[block] = 1
        self.alloc_count += 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)

    def release(self, blocks: List[int]) -> None:
        """Drop one reference per block.  A refcount reaching zero returns
        the block to the free pool — still registered, so a later request
        with the same prefix can revive it.  Over-release is rejected at
        the offending call, BEFORE any refcount moves — handing one
        physical block back twice would later alias two lanes' KV writes."""
        if len(set(blocks)) != len(blocks):
            raise ValueError(f"duplicate block ids in release: {blocks}")
        for b in blocks:
            if not 1 <= b <= self.n_blocks:
                raise ValueError(f"block id {b} outside the usable range")
            if b not in self._ref:
                raise ValueError(f"double free: block {b} is not allocated")
        self.version += 1
        for b in blocks:
            rc = self._ref[b] - 1
            self._track_shared(rc + 1, rc)
            if rc:
                self._ref[b] = rc
                continue
            del self._ref[b]
            if b in self._key_of:
                self._free_cached[b] = None  # MRU end of the LRU order
            else:
                self._free_plain.append(b)
            self.free_count += 1

    def cow_split(self, block: int) -> Optional[int]:
        """Copy-on-write: give the caller a private block in place of a
        SHARED one it is about to write.  Allocates the replacement, drops
        one reference on the original (which keeps its content and its
        cache entry), and returns the new id — the caller must copy the
        device-side content and patch its block table.  None (no side
        effects) when the pool is exhausted."""
        if self._ref.get(block, 0) < 2:
            raise ValueError(f"cow_split of unshared block {block}")
        fresh = self.allocate_one()
        if fresh is None:
            return None
        rc = self._ref[block]
        self._ref[block] = rc - 1
        self._track_shared(rc, rc - 1)
        self.cow_splits += 1
        return fresh

    # ------------------------------------------------------------------
    # content cache
    # ------------------------------------------------------------------
    def _forget(self, block: int) -> None:
        key = self._key_of.pop(block, None)
        if key is None:
            return
        self.version += 1
        self._tokens_of.pop(block, None)
        self._by_key.pop(key, None)
        parent = self._parent_of.pop(block, None)
        peers = self._by_parent.get(parent)
        if peers is not None:
            peers.discard(block)
            if not peers:
                del self._by_parent[parent]

    def flush_cache(self) -> int:
        """Forget EVERY content registration (a zombie worker rejoining
        after a reboot has cold memory: content-addressed hits against
        its old registrations would serve garbage K/V).  Refcount-zero
        cached blocks return to the plain free pool; blocks still held
        by live lanes stay allocated but leave the match index.  Returns
        the number of registrations dropped."""
        dropped = 0
        while self._free_cached:
            b, _ = self._free_cached.popitem(last=False)
            self._forget(b)
            self._free_plain.append(b)
            dropped += 1
        for b in list(self._key_of):     # still-referenced registrations
            self._forget(b)
            dropped += 1
        return dropped

    def uncache(self, block: int) -> None:
        """Drop a block's registration because its content is about to
        diverge (sole-holder write into a revived cached block)."""
        if self._ref.get(block, 0) != 1:
            raise ValueError(f"uncache of block {block} with refcount != 1")
        self._forget(block)

    def register(self, blocks: Sequence[int], tokens: Sequence[int]) -> int:
        """Enter every FULL block of ``tokens`` into the content cache.

        ``blocks`` is the lane's block table prefix and ``tokens`` the
        token content actually written through it; the trailing partial
        block (if any) is ignored.  Blocks already registered, or whose
        key is already held by another block, are skipped (first writer
        stays canonical).  Returns how many new entries were made."""
        bs = self.block_size
        parent: Optional[bytes] = None
        added = 0
        for i in range(len(tokens) // bs):
            b = blocks[i]
            chunk = tuple(int(t) for t in tokens[i * bs : (i + 1) * bs])
            key = _chain_key(parent, chunk)
            if b in self._key_of:
                # consistent re-registration keeps the existing entry; a
                # CHANGED key means the block was rewritten while cached —
                # a bookkeeping bug upstream, not a cache policy choice
                if self._key_of[b] != key:
                    raise ValueError(f"block {b} re-registered with new content")
            elif key not in self._by_key:
                self.version += 1
                self._key_of[b] = key
                self._tokens_of[b] = chunk
                self._parent_of[b] = parent
                self._by_key[key] = b
                self._by_parent.setdefault(parent, set()).add(b)
                added += 1
            parent = key
        return added

    def match_prefix(self, tokens: Sequence[int]) -> PrefixMatch:
        """Longest cached prefix of ``tokens`` (pure query, no refs).

        Full ``block_size`` chunks match by chained digest; when EVERY
        full chunk matched, the trailing partial chunk may additionally
        match the head of a cached block (``tail_partial`` — the caller
        shares that block and must COW before its first write into it)."""
        bs = self.block_size
        out: List[int] = []
        parent: Optional[bytes] = None
        n = 0
        n_full = len(tokens) // bs
        for i in range(n_full):
            chunk = tuple(int(t) for t in tokens[i * bs : (i + 1) * bs])
            key = _chain_key(parent, chunk)
            b = self._by_key.get(key)
            if b is None:
                return PrefixMatch(tuple(out), n, False)
            out.append(b)
            parent = key
            n += bs
        rem = len(tokens) - n_full * bs
        if rem:
            tail = tuple(int(t) for t in tokens[n_full * bs :])
            for b in self._by_parent.get(parent, ()):
                if self._tokens_of[b][:rem] == tail:
                    out.append(b)
                    return PrefixMatch(tuple(out), n + rem, True)
        return PrefixMatch(tuple(out), n, False)
