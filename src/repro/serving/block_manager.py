"""Paged KV-cache block accounting for the serving engine.

The KV pool is a fixed set of fixed-size blocks (``block_size`` token
positions each, all layers ride together in the model-side pool arrays);
a lane's cache is the ordered list of blocks in its block table, so
admission capacity is bound by *live tokens*, not by lanes times the
worst-case sequence length.

Block id 0 is RESERVED as the sink: free decode lanes and right-pad
positions scatter their garbage writes there, so the manager hands out
ids ``1..n_blocks`` only.

Watermark: ``can_admit`` keeps ``watermark_blocks`` free blocks in
reserve for decode-time growth of already-running lanes — admitting up
to the last block converts every subsequent grow into a preemption.
Growth allocation (``allocate_one``) ignores the watermark; running
requests always get priority over queued ones.
"""

from __future__ import annotations

from typing import List, Optional


class BlockManager:
    """Free-list allocator over ``n_blocks`` usable KV blocks."""

    def __init__(self, n_blocks: int, block_size: int, watermark_frac: float = 0.0):
        if n_blocks < 1:
            raise ValueError(f"need at least one usable block, got {n_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if not 0.0 <= watermark_frac < 1.0:
            raise ValueError(f"watermark_frac must be in [0, 1), got {watermark_frac}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.watermark_blocks = int(watermark_frac * n_blocks)
        # LIFO free list: recently-freed blocks are re-used first
        self._free: List[int] = list(range(n_blocks, 0, -1))
        self._allocated: set = set()
        self.peak_in_use = 0
        self.alloc_count = 0
        self.free_count = 0

    # ------------------------------------------------------------------
    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_blocks - len(self._free)

    @property
    def utilization(self) -> float:
        return self.in_use / self.n_blocks

    def blocks_needed(self, n_tokens: int) -> int:
        """Blocks covering ``n_tokens`` cache positions (at least one)."""
        return max(1, -(-n_tokens // self.block_size))

    def can_admit(self, n: int) -> bool:
        """Whether ``n`` blocks may go to a NEW request (watermark applies)."""
        return len(self._free) - n >= self.watermark_blocks

    # ------------------------------------------------------------------
    def allocate(self, n: int) -> Optional[List[int]]:
        """Take ``n`` blocks (no watermark), or None without side effects."""
        if n > len(self._free):
            return None
        taken = [self._free.pop() for _ in range(n)]
        self._allocated.update(taken)
        self.alloc_count += n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return taken

    def allocate_one(self) -> Optional[int]:
        got = self.allocate(1)
        return got[0] if got else None

    def release(self, blocks: List[int]) -> None:
        """Return blocks to the free list.  A double free is rejected at
        the offending call, BEFORE the free list is touched — a duplicate
        id on the list would later hand one physical block to two lanes,
        silently aliasing their KV writes."""
        if len(set(blocks)) != len(blocks):
            raise ValueError(f"duplicate block ids in release: {blocks}")
        for b in blocks:
            if not 1 <= b <= self.n_blocks:
                raise ValueError(f"block id {b} outside the usable range")
            if b not in self._allocated:
                raise ValueError(f"double free: block {b} is not allocated")
        self._allocated.difference_update(blocks)
        self._free.extend(reversed(blocks))
        self.free_count += len(blocks)
