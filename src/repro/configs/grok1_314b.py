"""grok-1-314b — [moe] 8 experts top-2.

64L d_model=6144 48H kv=8 d_ff=32768 vocab=131072.
[hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072,
    n_experts=8, top_k=2,
    rope_theta=1e4, act="gelu", glu=True,
    source="[hf:xai-org/grok-1; unverified]",
)
