"""llama4-scout-17b-a16e — [moe] 16 experts top-1 + shared expert, early fusion.

48L d_model=5120 40H kv=8 d_ff=8192 vocab=202048.  Long context via
chunked-local (iRoPE-style) attention, window 8192 — this is what makes the
``long_500k`` cell sub-quadratic (see DESIGN.md §4).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    n_experts=16, top_k=1, n_shared_experts=1,
    attention="chunked_local", chunk_size=8192,
    rope_theta=5e5, act="silu", glu=True,
    source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
)
