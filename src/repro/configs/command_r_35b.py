"""command-r-35b — [dense] GQA, no-bias, tied embeddings, 256k vocab.

40L d_model=8192 64H kv=8 d_ff=22528 vocab=256000.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22528, vocab_size=256000,
    rope_theta=4e6, act="silu", glu=True, tie_embeddings=True,
    source="[hf:CohereForAI/c4ai-command-r-v01; unverified]",
)
