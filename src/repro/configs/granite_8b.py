"""granite-8b — [dense] llama-arch, code.  36L d_model=4096 32H kv=8
d_ff=14336 vocab=49152.  [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=49152,
    rope_theta=1e7, act="silu", glu=True, tie_embeddings=True,
    source="[arXiv:2405.04324; hf]",
)
