"""whisper-small — [audio] enc-dec, conv frontend (stub).

12L decoder + 12L encoder, d_model=768, 12H (kv=12), d_ff=3072, vocab=51865.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=51865,
    n_enc_layers=12, frontend="audio", frontend_seq=1500,
    attention="full", act="gelu", glu=False, tie_embeddings=True,
    source="[arXiv:2212.04356; unverified]",
)
