"""ResNet-34 — the PAPER'S OWN model (§4.1 parallel training experiment).

[arXiv:1512.03385].  Stage counts (3,4,6,3), channels (64,128,256,512).
Used by benchmarks/bench_pipeline.py to reproduce the paper's speedup claims
and by examples/pipeline_train.py.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    arch_id: str = "resnet34"
    stages: Tuple[int, ...] = (3, 4, 6, 3)
    channels: Tuple[int, ...] = (64, 128, 256, 512)
    n_classes: int = 1000
    img_size: int = 224
    source: str = "[arXiv:1512.03385; paper's own model]"


CONFIG = ResNetConfig()

# Reduced config for CPU tests/examples
MINI = ResNetConfig(arch_id="resnet34-mini", stages=(1, 1, 1, 1),
                    channels=(8, 16, 32, 64), n_classes=10, img_size=32)
