"""zamba2-7b — [hybrid] Mamba2 backbone + shared attention blocks.

81L, d_model=3584, shared attn block 32H (kv=32), d_ff=14336, vocab=32000,
ssm_state=64.  The attention+MLP block is a single SHARED set of weights
applied every ``attn_every`` layers (zamba2's signature trick).
[arXiv:2411.15242; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_conv=4, attn_every=6,
    act="silu", glu=True, tie_embeddings=True,
    source="[arXiv:2411.15242; unverified]",
)
