"""mistral-nemo-12b — [dense] 128k-context GQA transformer.

40L, d_model=5120, 32H of head_dim 128 (q_dim 4096), kv=8, d_ff=14336,
vocab=131072.  [hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    rope_theta=1e6, act="silu", glu=True,
    source="[hf:mistralai/Mistral-Nemo-Base-2407; hf]",
)
