"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    LONG_500K,
    DECODE_32K,
    PREFILL_32K,
    TRAIN_4K,
    SHAPES,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    shape_applicable,
)

from repro.configs import (  # noqa: E402
    command_r_35b,
    granite_8b,
    grok1_314b,
    internvl2_1b,
    llama4_scout_17b_a16e,
    mistral_nemo_12b,
    rwkv6_1p6b,
    whisper_small,
    yi_34b,
    zamba2_7b,
)

_MODULES = (
    whisper_small, zamba2_7b, mistral_nemo_12b, yi_34b, granite_8b,
    command_r_35b, llama4_scout_17b_a16e, grok1_314b, rwkv6_1p6b, internvl2_1b,
)

REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.arch_id: m.CONFIG for m in _MODULES}

ARCH_IDS: List[str] = list(REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    try:
        return REGISTRY[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test-sized variant of the same family (CPU-runnable).

    Keeps every structural feature (GQA ratio, MoE, hybrid pattern, frontends,
    enc-dec) while shrinking width/depth/vocab.
    """
    kw = dataclasses.asdict(cfg)
    gqa_ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    kw.update(
        arch_id=cfg.arch_id + "-smoke",
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, 4 // gqa_ratio),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
    )
    if cfg.n_experts:
        kw.update(n_experts=min(cfg.n_experts, 4), top_k=min(cfg.top_k, 2))
    if cfg.family in ("ssm", "hybrid") and not cfg.rwkv:
        kw.update(ssm_state=16, ssm_headdim=32,
                  attn_every=2 if cfg.attn_every else 0)
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2)
    if cfg.frontend:
        kw.update(frontend_seq=16)
    if cfg.attention == "chunked_local":
        kw.update(chunk_size=32)
    return ModelConfig(**kw)


SMOKE_SHAPE = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")
