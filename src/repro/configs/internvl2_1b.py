"""internvl2-1b — [vlm] InternViT frontend (stub) + InternLM2/Qwen2-class LM.

24L d_model=896 14H kv=2 d_ff=4864 vocab=151655.  [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151655,
    frontend="vision", frontend_seq=1024, qkv_bias=True,
    rope_theta=1e6, act="silu", glu=True, tie_embeddings=True,
    source="[arXiv:2404.16821; hf]",
)
