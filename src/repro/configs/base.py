"""Config system: model architecture, input shapes, and run/distribution config.

Every assigned architecture gets a module under ``repro.configs`` exporting a
``CONFIG: ModelConfig``; the registry in ``repro.configs`` maps ``--arch`` ids
to them.  Shapes are global (the assignment pairs every LM arch with the same
four shapes); per-arch applicability is encoded in :func:`shape_applicable`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model architecture
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0

    # SSM (Mamba2) / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    attn_every: int = 0            # hybrid: apply shared attn block every k layers

    # RWKV6
    rwkv: bool = False

    # Attention
    attention: str = "full"        # full | chunked_local
    chunk_size: int = 8192         # for chunked_local
    rope_theta: float = 1e6
    qkv_bias: bool = False

    # Encoder-decoder (whisper) / multimodal frontends
    n_enc_layers: int = 0
    frontend: Optional[str] = None  # audio | vision | None
    frontend_seq: int = 0           # frames/patches emitted by the (stubbed) frontend

    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"               # silu | gelu
    glu: bool = True                # gated MLP (3 matrices) vs plain (2)

    source: str = ""                # provenance note [source; tier]

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    # ---- derived quantities -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def mlp_params(self) -> int:
        mats = 3 if self.glu else 2
        return mats * self.d_model * self.d_ff

    def attn_params(self) -> int:
        return (self.d_model * self.q_dim + 2 * self.d_model * self.kv_dim
                + self.q_dim * self.d_model)

    def layer_params(self) -> int:
        """Approximate params of one decoder block (norms excluded)."""
        if self.rwkv:
            tmix = 5 * self.d_model * self.d_model + 3 * self.d_model * 96
            cmix = 2 * self.d_model * self.d_ff
            return tmix + cmix
        if self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * self.d_model
            n_g = max(1, self.n_kv_heads) if self.family == "ssm" else 1
            n_g = 1
            conv_dim = d_in + 2 * n_g * self.ssm_state
            nheads = d_in // self.ssm_headdim
            in_proj = self.d_model * (2 * d_in + 2 * n_g * self.ssm_state + nheads)
            out_proj = d_in * self.d_model
            mamba = in_proj + out_proj + conv_dim * self.ssm_conv
            return mamba
        moe = 0
        if self.n_experts:
            mats = 3 if self.glu else 2
            moe = (self.n_experts + self.n_shared_experts) * mats * self.d_model * self.d_ff
            moe += self.d_model * self.n_experts  # router
            return self.attn_params() + moe
        return self.attn_params() + self.mlp_params()

    def embed_params(self) -> int:
        mult = 1 if self.tie_embeddings else 2
        return mult * self.vocab_size * self.d_model

    def total_params(self) -> int:
        n = self.n_layers * self.layer_params() + self.embed_params()
        if self.family == "hybrid" and self.attn_every:
            # one shared attention+MLP block (zamba2-style), applied periodically
            n += self.attn_params() + self.mlp_params()
        if self.n_enc_layers:
            # encoder blocks (self-attn + mlp) + decoder cross-attn already counted? no:
            # decoder blocks in enc-dec get an extra cross-attention
            n += self.n_enc_layers * (self.attn_params() + self.mlp_params())
            n += self.n_layers * self.attn_params()  # cross-attn in each decoder layer
        return n

    def active_params(self) -> int:
        """Params touched per token (MoE: top_k + shared experts)."""
        if not self.n_experts:
            return self.total_params()
        mats = 3 if self.glu else 2
        active_moe = (self.top_k + self.n_shared_experts) * mats * self.d_model * self.d_ff
        per_layer = self.attn_params() + active_moe + self.d_model * self.n_experts
        return self.n_layers * per_layer + self.embed_params()


# ---------------------------------------------------------------------------
# Input shapes (assigned: same 4 shapes for every LM arch)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch        # one new token per sequence
        return self.global_batch * self.seq_len


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, per the assignment rules.

    ``long_500k`` needs sub-quadratic attention: runs for SSM / hybrid /
    linear-attention / chunked-local archs, skipped for pure full attention.
    """
    if shape.name == "long_500k":
        sub_quadratic = (
            model.family in ("ssm", "hybrid")
            or model.rwkv
            or model.attention == "chunked_local"
        )
        if not sub_quadratic:
            return False, ("full quadratic attention at seq 524288 — no "
                           "sub-quadratic path in this config (see DESIGN.md §4)")
    return True, ""


# ---------------------------------------------------------------------------
# Run / distribution config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RunConfig:
    strategy: str = "auto"         # auto | pp_shardmap | gspmd_tp | gspmd_pp
    schedule: str = "hybrid"       # gpipe | hybrid    (pp schedules; paper default: hybrid)
    pp_stages: int = 0             # 0 = choose from mesh
    microbatches: int = 0          # 0 = choose (>= stages)
    remat: bool = True
    use_kernels: bool = False      # route attention/ssm through Pallas kernels
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    fsdp: bool = False             # shard params over "data" too (gspmd_tp)
    zero1: bool = True             # shard optimizer moments over "data"
    grad_compression: str = "none" # reserved: none | int8 (error-feedback)
    grad_accum: int = 1            # sequential microbatches in gspmd_tp train
    seq_shard: bool = False        # sequence-sharded residual stream
    #                                (Megatron-SP analogue via GSPMD constraint)
    seed: int = 0
    # Dry-run fidelity: unroll the layer loop so cost_analysis/HLO collective
    # counts are exact (scan bodies are only counted once by XLA cost analysis).
    unroll_layers: bool = False
