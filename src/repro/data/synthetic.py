"""Deterministic synthetic data pipelines (offline container; DESIGN §8.6).

Token streams have learnable structure (a fixed random bigram transition
table) so training loss measurably descends — a pure-uniform stream would
plateau at ln(V) and hide optimizer bugs.  Image batches are class-templated
noise for the ResNet reproduction.

Host-sharded: each data-parallel host pulls only its shard (deterministic in
(seed, step, shard) — restart-safe by construction, the checkpoint stores
just the step cursor).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 8          # bigram successors per token (entropy ~ln(8))


class TokenPipeline:
    """Bigram-structured token stream, shardable and seekable."""

    def __init__(self, cfg: DataConfig, shard: int = 0, n_shards: int = 1):
        assert cfg.global_batch % n_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards
        rng = np.random.default_rng(cfg.seed)
        # fixed transition table: token t may be followed by branching tokens
        self.table = rng.integers(
            0, cfg.vocab_size, size=(cfg.vocab_size, cfg.branching),
            dtype=np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b = cfg.global_batch // self.n_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 64 + self.shard)
        toks = np.empty((b, cfg.seq_len), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=b)
        choices = rng.integers(0, cfg.branching, size=(b, cfg.seq_len - 1))
        for t in range(1, cfg.seq_len):
            toks[:, t] = self.table[toks[:, t - 1], choices[:, t - 1]]
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class FrontendPipeline(TokenPipeline):
    """Adds stub frame/patch embeddings (the [audio]/[vlm] frontends)."""

    def __init__(self, cfg: DataConfig, frontend_seq: int, d_model: int,
                 key: str = "frontend", shard: int = 0, n_shards: int = 1):
        super().__init__(cfg, shard, n_shards)
        self.frontend_seq = frontend_seq
        self.d_model = d_model
        self.key = key

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        out = super().batch(step)
        b = out["tokens"].shape[0]
        rng = np.random.default_rng(
            (self.cfg.seed * 7_000_003 + step) * 64 + self.shard + 17)
        out[self.key] = (0.1 * rng.standard_normal(
            (b, self.frontend_seq, self.d_model))).astype(np.float32)
        return out


class ImagePipeline:
    """Class-templated noisy images (ResNet §4.1 reproduction)."""

    def __init__(self, n_classes: int, img_size: int, batch: int,
                 seed: int = 0, shard: int = 0, n_shards: int = 1):
        self.n_classes = n_classes
        self.img = img_size
        self.batch = batch // n_shards
        self.seed = seed
        self.shard = shard
        rng = np.random.default_rng(seed)
        self.templates = rng.standard_normal(
            (n_classes, img_size, img_size, 3)).astype(np.float32)

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng((self.seed + step) * 64 + self.shard)
        labels = rng.integers(0, self.n_classes, size=self.batch)
        x = self.templates[labels] + 0.5 * rng.standard_normal(
            (self.batch, self.img, self.img, 3)).astype(np.float32)
        return x.astype(np.float32), labels.astype(np.int32)


class Prefetcher:
    """Double-buffered background prefetch (host -> device overlap)."""

    def __init__(self, it: Iterator, depth: int = 2):
        import queue
        import threading

        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.done = False

        def worker():
            for item in it:
                if self.done:
                    return
                self.q.put(item)
            self.q.put(None)

        self.thread = threading.Thread(target=worker, daemon=True)
        self.thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self.done = True
