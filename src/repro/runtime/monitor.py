"""Thermal/straggler monitor (paper §4.2).

The paper watched Xcode's thermal states (Minimal -> Fair -> Serious) while
the iPhone's per-batch time crept from ~15.3 s to ~16.9 s.  Here the same
state machine runs on per-step latency telemetry: an EWMA per worker, state
thresholds expressed as slowdown ratios vs the worker's calibration
baseline, and a recommendation hook the elastic policies consume.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, List, Optional


class ThermalState(enum.Enum):
    MINIMAL = "Minimal"
    FAIR = "Fair"
    SERIOUS = "Serious"
    CRITICAL = "Critical"


# slowdown-vs-baseline thresholds (paper Fig. 6: Fair ~batch 13 at ~1.02x,
# Serious ~batch 17 at ~1.05-1.10x, sustained)
THRESHOLDS = {
    ThermalState.MINIMAL: 1.00,
    ThermalState.FAIR: 1.02,
    ThermalState.SERIOUS: 1.08,
    ThermalState.CRITICAL: 1.25,
}


@dataclasses.dataclass
class WorkerStats:
    worker: str
    baseline_s: Optional[float] = None
    ewma_s: Optional[float] = None
    state: ThermalState = ThermalState.MINIMAL
    steps: int = 0
    state_history: List[ThermalState] = dataclasses.field(default_factory=list)

    @property
    def slowdown(self) -> float:
        if not self.baseline_s or not self.ewma_s:
            return 1.0
        return self.ewma_s / self.baseline_s


class ThermalMonitor:
    """EWMA latency tracking + paper-style thermal state machine."""

    def __init__(self, alpha: float = 0.25, calibration_steps: int = 3,
                 warmup_skip: int = 1):
        self.alpha = alpha
        self.calibration_steps = calibration_steps
        self.warmup_skip = warmup_skip       # drop compile-step outliers
        self.workers: Dict[str, WorkerStats] = {}

    def observe(self, worker: str, step_seconds: float) -> WorkerStats:
        ws = self.workers.setdefault(worker, WorkerStats(worker))
        ws.steps += 1
        if ws.steps <= self.warmup_skip:
            ws.state_history.append(ws.state)
            return ws
        if ws.ewma_s is None:
            ws.ewma_s = step_seconds
        else:
            ws.ewma_s = (1 - self.alpha) * ws.ewma_s + self.alpha * step_seconds
        if ws.steps == self.warmup_skip + self.calibration_steps:
            ws.baseline_s = ws.ewma_s
        ws.state = self._state_of(ws.slowdown)
        ws.state_history.append(ws.state)
        return ws

    @staticmethod
    def _state_of(slowdown: float) -> ThermalState:
        state = ThermalState.MINIMAL
        for st, thr in THRESHOLDS.items():
            if slowdown >= thr:
                state = st
        return state

    def stragglers(self, min_state: ThermalState = ThermalState.SERIOUS
                   ) -> List[WorkerStats]:
        order = list(ThermalState)
        return [w for w in self.workers.values()
                if order.index(w.state) >= order.index(min_state)]

    def occupancy(self) -> Dict[str, Dict[str, float]]:
        """Fraction of observations each worker spent in each thermal state
        (states never entered are omitted) — the fleet's per-worker
        thermal-state occupancy metric."""
        out: Dict[str, Dict[str, float]] = {}
        for w in self.workers.values():
            n = len(w.state_history)
            if not n:
                out[w.worker] = {}
                continue
            out[w.worker] = {
                s.value: w.state_history.count(s) / n
                for s in ThermalState if s in w.state_history}
        return out

    def summary(self) -> Dict[str, dict]:
        return {w.worker: {"state": w.state.value,
                           "slowdown": round(w.slowdown, 4),
                           "ewma_s": w.ewma_s}
                for w in self.workers.values()}
