"""Fault / throttle injection for testing the runtime (no real failures on
a 1-CPU container; a real fleet raises the same exceptions from XLA)."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional


class WorkerFailure(RuntimeError):
    def __init__(self, worker: str, step: int):
        super().__init__(f"worker {worker} failed at step {step}")
        self.worker = worker
        self.step = step


@dataclasses.dataclass
class FaultPlan:
    """fail_at: step -> worker ; throttle: worker -> (start_step, factor, tau)"""
    fail_at: Dict[int, str] = dataclasses.field(default_factory=dict)
    throttle: Dict[str, tuple] = dataclasses.field(default_factory=dict)

    def check(self, step: int):
        if step in self.fail_at:
            raise WorkerFailure(self.fail_at.pop(step), step)

    def slowdown(self, worker: str, step: int) -> float:
        """Thermal-curve multiplier (paper Fig. 6 shape: ramp to plateau)."""
        if worker not in self.throttle:
            return 1.0
        start, factor, tau = self.throttle[worker]
        if step < start:
            return 1.0
        import math

        ramp = 1.0 - math.exp(-(step - start) / max(tau, 1e-9))
        return 1.0 + (factor - 1.0) * ramp
