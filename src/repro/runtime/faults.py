"""Fault / throttle injection for testing the runtime and serving planes.

No real failures happen on a 1-CPU container (a real fleet raises the same
exceptions from XLA), so faults are *injected* from seeded plans:

* :class:`FaultPlan` — the training-runtime face: step-indexed worker
  failures (raised as :class:`WorkerFailure` for the elastic trainer to
  catch) plus per-worker thermal throttle ramps.  ``check`` is
  **non-mutating**: a replayed seeded run sees the same failures every
  time (``seeded_replay_check`` compatibility) — recovery bookkeeping
  belongs to the *consumer* (the trainer remembers which failure steps it
  already survived), not to the plan.

* :class:`KillTrace` — the serving-fleet face: a seeded, time-indexed
  schedule of worker deaths for the failure plane
  (:mod:`repro.serving.failover`).  Three kinds model the paper's phone
  pathologies:

  - ``"crash"`` — battery death: the worker is gone for good.
  - ``"partition"`` — network drop / iOS backgrounding: the worker keeps
    its memory (KV cache, params) and returns after ``down_s``; if it
    returns before the fleet's dead-threshold fires, the outage is a
    transparent blip.
  - ``"zombie"`` — thermal shutdown then reboot: the worker returns after
    ``down_s`` but COLD — caches flushed, params re-warmed.

:func:`make_kill_trace` draws a trace from a seeded
``numpy.random.Generator`` (never stdlib ``random`` — repro-lint R002):
the same seed yields the same deaths, so every chaos test and bench is a
pure function of its seed.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, Sequence, Tuple, Union

import numpy as np

KILL_KINDS = ("crash", "partition", "zombie")


class WorkerFailure(RuntimeError):
    def __init__(self, worker: str, step: int):
        super().__init__(f"worker {worker} failed at step {step}")
        self.worker = worker
        self.step = step


@dataclasses.dataclass
class FaultPlan:
    """fail_at: step -> worker ; throttle: worker -> (start_step, factor, tau)"""
    fail_at: Dict[int, str] = dataclasses.field(default_factory=dict)
    throttle: Dict[str, tuple] = dataclasses.field(default_factory=dict)

    def check(self, step: int) -> None:
        """Raise :class:`WorkerFailure` if a failure is planned at ``step``.

        Non-mutating: checking the same step twice raises twice.  The plan
        is a pure schedule — a seeded replay must see identical failures
        on every run, so surviving a failure is recorded by whoever caught
        it (see ``Trainer.run``), never by editing the plan."""
        worker = self.fail_at.get(step)
        if worker is not None:
            raise WorkerFailure(worker, step)

    def slowdown(self, worker: str, step: int) -> float:
        """Thermal-curve multiplier (paper Fig. 6 shape: ramp to plateau)."""
        if worker not in self.throttle:
            return 1.0
        start, factor, tau = self.throttle[worker]
        if step < start:
            return 1.0
        ramp = 1.0 - math.exp(-(step - start) / max(tau, 1e-9))
        return 1.0 + (factor - 1.0) * ramp


# ---------------------------------------------------------------------------
# serving-plane kill traces
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class KillEvent:
    """One scheduled worker death.

    ``worker`` is a fleet worker/member name (str) or a SimFleet row index
    (int).  ``down_s`` only applies to ``partition`` / ``zombie`` — how
    long the worker stays unreachable before returning (``inf`` = never,
    which a ``crash`` always is)."""
    t_s: float
    worker: Union[str, int]
    kind: str = "crash"
    down_s: float = math.inf

    def __post_init__(self) -> None:
        if self.kind not in KILL_KINDS:
            raise ValueError(f"kill kind {self.kind!r} not in {KILL_KINDS}")
        if self.t_s < 0 or self.down_s <= 0:
            raise ValueError(f"kill event needs t_s >= 0 and down_s > 0, "
                             f"got t_s={self.t_s}, down_s={self.down_s}")

    @property
    def returns(self) -> bool:
        return self.kind != "crash" and math.isfinite(self.down_s)


@dataclasses.dataclass(frozen=True)
class KillTrace:
    """A time-ordered schedule of :class:`KillEvent`; iterable, indexable,
    and safe to share between a fleet and its reference run (frozen)."""
    events: Tuple[KillEvent, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "events", tuple(
            sorted(self.events, key=lambda e: (e.t_s, str(e.worker)))))

    def __iter__(self) -> Iterator[KillEvent]:
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def deaths(self) -> int:
        """Events that remove a worker for good (crashes plus kills that
        never return)."""
        return sum(1 for e in self.events if not e.returns)


def make_kill_trace(workers: Sequence[Union[str, int]], n_kills: int, *,
                    t0_s: float = 0.0, t1_s: float = 10.0, seed: int = 0,
                    kinds: Sequence[str] = ("crash",),
                    down_s: Tuple[float, float] = (0.5, 2.0)) -> KillTrace:
    """Draw a seeded kill trace: ``n_kills`` distinct workers die at
    uniform times in ``[t0_s, t1_s)`` with kinds cycled from ``kinds``
    (deterministically shuffled), partition/zombie outages lasting uniform
    ``down_s`` seconds.  Same seed, same trace — the chaos harness's whole
    input is (workers, seed)."""
    if n_kills > len(workers):
        raise ValueError(f"cannot kill {n_kills} of {len(workers)} workers "
                         "(each worker dies at most once per trace)")
    for k in kinds:
        if k not in KILL_KINDS:
            raise ValueError(f"kill kind {k!r} not in {KILL_KINDS}")
    rng = np.random.default_rng(seed)
    victims = [workers[i] for i in rng.permutation(len(workers))[:n_kills]]
    times = sorted(float(t) for t in rng.uniform(t0_s, t1_s, size=n_kills))
    events = []
    for t, w in zip(times, victims):
        kind = kinds[int(rng.integers(len(kinds)))]
        dur = math.inf if kind == "crash" \
            else float(rng.uniform(down_s[0], down_s[1]))
        events.append(KillEvent(t_s=t, worker=w, kind=kind, down_s=dur))
    return KillTrace(tuple(events))
