"""Fault-tolerant training loop.

Wires together: step builder (any strategy) -> data pipeline -> async
wire-codec checkpoints -> thermal monitor -> mitigation policies -> failure
recovery (restore latest checkpoint and resume, repartitioning if the fleet
changed).  Designed so the same loop drives a 2-device CPU test and a
512-chip pod (the step function and mesh are injected).
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint.store import AsyncCheckpointer, latest_step, restore
from repro.runtime.faults import FaultPlan, WorkerFailure
from repro.runtime.monitor import ThermalMonitor


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    max_restarts: int = 3
    worker_name: str = "worker0"


class Trainer:
    def __init__(self, tcfg: TrainerConfig, step_fn: Callable,
                 init_state: Optional[Callable[[], tuple]] = None,
                 data_iter_fn: Optional[Callable[[int], Iterator]] = None,
                 shardings: Any = None,
                 fault_plan: Optional[FaultPlan] = None,
                 on_metrics: Optional[Callable[[int, dict], None]] = None,
                 clock: Callable[[], float] = time.perf_counter):
        """init_state() -> (params, opt_state); data_iter_fn(start_step)
        yields batches; step_fn(params, opt, batch) -> (params, opt, metrics).

        ``clock`` is the time source step latencies are measured on — the
        wall clock by default, or a sim clock (e.g. a fleet's ``sim_t``
        reader) so federated rounds driven by :mod:`repro.serving.train_plane`
        time themselves in simulated seconds.  ``init_state`` /
        ``data_iter_fn`` are only required by :meth:`run`; a step-driven
        caller that owns its state and batches (the fed plane) may omit
        them and call :meth:`train_step` directly."""
        self.cfg = tcfg
        self.step_fn = step_fn
        self.init_state = init_state
        self.data_iter_fn = data_iter_fn
        self.shardings = shardings
        self.faults = fault_plan or FaultPlan()
        self.monitor = ThermalMonitor()
        self.ckpt = AsyncCheckpointer(Path(tcfg.ckpt_dir))
        self.on_metrics = on_metrics
        self.clock = clock
        self.history: List[dict] = []
        self.restarts = 0
        self._recovered: set = set()     # failure steps already survived

    # ------------------------------------------------------------------
    def train_step(self, params, opt, batch, step: int):
        """One fault-checked, clock-timed, thermally-observed step — the
        unit :meth:`run` loops over and the fed plane drives directly.
        Returns ``(params, opt, record)``."""
        if step not in self._recovered:
            self.faults.check(step)                   # injected failures
        t0 = self.clock()
        params, opt, metrics = self.step_fn(params, opt, batch)
        loss = float(metrics["loss"])  # repro-lint: allow[R004] the step's one deliberate loss transfer, timed as part of dt
        dt = self.clock() - t0
        dt *= self.faults.slowdown(self.cfg.worker_name, step)
        ws = self.monitor.observe(self.cfg.worker_name, dt)
        rec = dict(step=step, loss=loss, step_s=dt,
                   thermal=ws.state.value, slowdown=round(ws.slowdown, 4))
        self.history.append(rec)
        if self.on_metrics:
            self.on_metrics(step, rec)
        return params, opt, rec

    # ------------------------------------------------------------------
    def _start_state(self):
        if self.init_state is None or self.data_iter_fn is None:
            raise ValueError("Trainer.run() needs init_state and "
                             "data_iter_fn; step-driven callers use "
                             "train_step() instead")
        params, opt = self.init_state()
        start = 0
        last = latest_step(Path(self.cfg.ckpt_dir))
        if last is not None:
            tree, extra = restore(Path(self.cfg.ckpt_dir), last,
                                  like={"params": params, "opt": opt},
                                  shardings=self.shardings)
            params, opt = tree["params"], tree["opt"]
            start = int(extra.get("next_step", last))
            print(f"[trainer] restored step {last}, resuming at {start}")
        return params, opt, start

    def run(self) -> Dict[str, Any]:
        while True:
            try:
                return self._run_once()
            except WorkerFailure as e:
                # FaultPlan.check is non-mutating (seeded replays must see
                # every failure); the trainer records which failure steps
                # it already survived so a restart that resumes at or
                # before e.step doesn't re-trip the same fault forever
                self._recovered.add(e.step)
                self.restarts += 1
                print(f"[trainer] {e} — restart {self.restarts}/"
                      f"{self.cfg.max_restarts}")
                if self.restarts > self.cfg.max_restarts:
                    raise
                self.ckpt.wait()

    def _run_once(self) -> Dict[str, Any]:
        params, opt, start = self._start_state()
        data = self.data_iter_fn(start)
        losses = []
        for step in range(start, self.cfg.total_steps):
            batch = next(data)
            params, opt, rec = self.train_step(params, opt, batch, step)
            loss = rec["loss"]
            losses.append(loss)
            if step % self.cfg.log_every == 0:
                print(f"[trainer] step {step:5d} loss {loss:.4f} "
                      f"({rec['step_s']*1e3:.0f} ms, {rec['thermal']})")
            if (step + 1) % self.cfg.ckpt_every == 0:
                self.ckpt.save_async(step + 1,
                                     {"params": params, "opt": opt},
                                     extra={"next_step": step + 1})
        self.ckpt.wait()
        return {"params": params, "opt": opt,
                "losses": losses, "history": self.history}
