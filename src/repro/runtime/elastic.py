"""Elastic mitigation policies — the paper's §5.2 proposals, implemented.

Three policies the paper sketches for its thermal problem, generalised to a
fleet:

* ``SwapPolicy``       — "swapping between multiple iOS workers, letting one
  cool down while another worked": maintain hot spares; when a worker goes
  SERIOUS, promote a spare into its pipeline slot and send the hot one to the
  cooling pool (re-admitted at MINIMAL).
* ``DutyCyclePolicy``  — "regulating compute loads to short bursts": insert
  idle fractions for hot workers (modelled as a per-worker throughput
  multiplier the trainer applies to microbatch assignment).
* ``RebalancePolicy``  — repartition stage boundaries with the cost model so
  a throttled worker gets fewer layers (the paper's split-point search, rerun
  online with updated device rates).

Policies consume :class:`repro.runtime.monitor.ThermalMonitor` summaries and
emit Actions; the trainer / simulator executes them.

The same mitigations generalise from trainer stage-swaps to **live serving
traffic** (consumed by :class:`repro.serving.fleet.ServingFleet`):
:class:`ServingElasticPolicy` emits ``drain`` (route new admissions away
from a hot worker), ``migrate`` (preempt its decode lanes token-identically
and re-admit them on a cooler worker) and ``duty_cycle`` (fewer decode
steps per fleet tick) actions, with hysteresis: a drained worker is
re-admitted (``undrain``) only once it cools back to MINIMAL.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Set

from repro.core.partition import SplitPlan, split_blocks
from repro.hw.specs import DeviceProfile
from repro.runtime.monitor import ThermalMonitor, ThermalState, WorkerStats


@dataclasses.dataclass(frozen=True)
class Action:
    # trainer kinds: swap | duty_cycle | rebalance | none
    # serving kinds: drain | undrain | migrate | duty_cycle
    # scale kinds:   scale_up | scale_down
    kind: str
    worker: str = ""
    detail: dict = dataclasses.field(default_factory=dict)


class SwapPolicy:
    """Hot-spare promotion (paper: 'pipelining the devices themselves')."""

    def __init__(self, spares: Sequence[str]):
        self.spares: List[str] = list(spares)
        self.cooling: List[str] = []

    def step(self, monitor: ThermalMonitor) -> List[Action]:
        actions = []
        # re-admit cooled workers
        for w in list(self.cooling):
            ws = monitor.workers.get(w)
            if ws and ws.state == ThermalState.MINIMAL:
                self.cooling.remove(w)
                self.spares.append(w)
        for ws in monitor.stragglers(ThermalState.SERIOUS):
            if ws.worker in self.cooling:
                continue
            if not self.spares:
                break
            spare = self.spares.pop(0)
            self.cooling.append(ws.worker)
            # the spare inherits the hot worker's telemetry slot fresh
            monitor.workers.pop(ws.worker, None)
            actions.append(Action("swap", ws.worker,
                                  {"replacement": spare}))
        return actions


class DutyCyclePolicy:
    """Short-burst load regulation: throttle assignment to hot workers."""

    def __init__(self, serious_duty: float = 0.6, fair_duty: float = 0.85):
        self.serious_duty = serious_duty
        self.fair_duty = fair_duty

    def step(self, monitor: ThermalMonitor) -> List[Action]:
        actions = []
        for ws in monitor.workers.values():
            duty = 1.0
            if ws.state == ThermalState.FAIR:
                duty = self.fair_duty
            elif ws.state in (ThermalState.SERIOUS, ThermalState.CRITICAL):
                duty = self.serious_duty
            if duty < 1.0:
                actions.append(Action("duty_cycle", ws.worker, {"duty": duty}))
        return actions


class RebalancePolicy:
    """Online re-split: feed throttled rates back into the cost model."""

    def __init__(self, costs, devices: Sequence[DeviceProfile],
                 efficiency: float = 0.5, train: bool = True):
        self.costs = costs
        self.devices = list(devices)
        self.efficiency = efficiency
        self.train = train
        self.current: Optional[SplitPlan] = None

    def step(self, monitor: ThermalMonitor,
             worker_order: Sequence[str]) -> List[Action]:
        derated = []
        for name, dev in zip(worker_order, self.devices):
            ws = monitor.workers.get(name)
            rate = 1.0 / ws.slowdown if ws else 1.0
            derated.append(dataclasses.replace(dev, flops=dev.flops * rate))
        plan = split_blocks(self.costs, derated, self.efficiency, self.train)
        if self.current is not None and plan.cuts == self.current.cuts:
            return []
        prev = self.current
        self.current = plan
        return [Action("rebalance", "",
                       {"cuts": list(plan.cuts),
                        "prev": list(prev.cuts) if prev else None,
                        "bottleneck_s": plan.bottleneck})]


class ServingElasticPolicy:
    """§5.2 mitigations applied to live serving traffic.

    Consumed by :class:`repro.serving.fleet.ServingFleet`: every fleet tick
    the policy reads the :class:`ThermalMonitor` and emits

    * ``drain`` when a worker reaches ``drain_at`` — the fleet routes new
      admissions away from it (its queued backlog still drains through it);
    * ``migrate`` (edge-triggered, once per hot episode) when it reaches
      ``migrate_at`` — the fleet preempts its decode lanes (frozen sampler
      PRNG + generated-token requeue keep the resume token-identical) and
      re-admits them on the coolest non-drained worker.  With
      ``migrate_queued`` its queued backlog is re-routed too;
    * ``duty_cycle`` (delegated to :class:`DutyCyclePolicy`) for every
      FAIR-or-hotter worker — the fleet runs it for a fraction of each
      tick, trading throughput for heat;
    * ``undrain`` once a drained worker cools back to MINIMAL (hysteresis:
      it must fully recover, not merely dip below ``drain_at``).
    """

    def __init__(self, drain_at: ThermalState = ThermalState.SERIOUS,
                 migrate_at: ThermalState = ThermalState.SERIOUS,
                 duty: Optional[DutyCyclePolicy] = None,
                 migrate_queued: bool = True,
                 migrate_lanes: Optional[int] = None):
        self.drain_at = drain_at
        self.migrate_at = migrate_at
        self.duty = duty or DutyCyclePolicy()
        self.migrate_queued = migrate_queued
        # None = evict every lane; an int bounds the eviction to the N
        # cheapest victims (the fleet orders them by recompute cost and
        # footprint — see ServingFleet.migrate)
        self.migrate_lanes = migrate_lanes
        self.draining: Set[str] = set()
        self._migrated: Set[str] = set()    # hot episodes already migrated

    def step(self, monitor: ThermalMonitor) -> List[Action]:
        order = list(ThermalState)
        actions: List[Action] = []
        for ws in monitor.workers.values():
            rank = order.index(ws.state)
            if rank >= order.index(self.drain_at):
                if ws.worker not in self.draining:
                    self.draining.add(ws.worker)
                    actions.append(Action("drain", ws.worker,
                                          {"state": ws.state.value}))
                if (ws.worker not in self._migrated
                        and rank >= order.index(self.migrate_at)):
                    self._migrated.add(ws.worker)
                    actions.append(Action(
                        "migrate", ws.worker,
                        {"state": ws.state.value,
                         "queued": self.migrate_queued,
                         "lanes": self.migrate_lanes}))
            elif (ws.state == ThermalState.MINIMAL
                    and ws.worker in self.draining):
                self.draining.discard(ws.worker)
                self._migrated.discard(ws.worker)
                actions.append(Action("undrain", ws.worker))
        actions.extend(self.duty.step(monitor))
        return actions


# ---------------------------------------------------------------------------
# fleet-size elasticity (scale plane)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FleetLoad:
    """One tick's aggregate load reading of a serving fleet — the signal an
    :class:`AutoscalePolicy` scales against.  Produced by
    :meth:`repro.serving.scale.SimFleet.load` (or any equivalent source)."""
    sim_t: float
    serving: int          # warmed, admitting workers (excl. retiring)
    warming: int          # scaled up, still streaming params over the link
    spare: int            # rows that could still be scaled up
    queue_depth: int      # requests queued across serving workers
    backlog_s: float      # mean predicted wait-to-first-token across workers
    backlog_max_s: float  # worst single worker's predicted wait
    hot_frac: float       # fraction of serving workers at SERIOUS or worse
    util_mean: float      # mean busy fraction of the last tick


class AutoscalePolicy:
    """Fleet-size sibling of :class:`ServingElasticPolicy`: spin replica
    workers (or split StageGroups — the fleet decides what a "row" is)
    up/down against queue backlog and thermal headroom.

    * **scale up** when predicted backlog exceeds ``target_wait_s`` or too
      many serving workers run hot (``hot_frac > hot_headroom`` — thermal
      pressure is capacity pressure on phones): add ``step_frac`` of the
      current fleet, bounded by spares and ``max_workers``.  New capacity
      is *not* free — the fleet charges each new worker's params over its
      link as warm-up bytes before it serves.
    * **scale down** when backlog stays below ``idle_wait_s`` and mean
      utilisation below ``idle_util`` for ``settle_reads`` consecutive
      readings: retire ``step_frac`` of the fleet (drain, then drop) down
      to ``min_workers``.  The sustained-low requirement plus
      ``cooldown_s`` between actions gives the same hysteresis flavour as
      ServingElasticPolicy's undrain rule — capacity should not flap with
      every burst.
    """

    def __init__(self, min_workers: int = 1, max_workers: int = 1 << 30, *,
                 target_wait_s: float = 1.0, idle_wait_s: float = 0.2,
                 hot_headroom: float = 0.25, idle_util: float = 0.35,
                 step_frac: float = 0.25, cooldown_s: float = 5.0,
                 settle_reads: int = 3):
        if min_workers < 0 or max_workers < min_workers:
            raise ValueError("need 0 <= min_workers <= max_workers")
        self.min_workers = min_workers
        self.max_workers = max_workers
        self.target_wait_s = target_wait_s
        self.idle_wait_s = idle_wait_s
        self.hot_headroom = hot_headroom
        self.idle_util = idle_util
        self.step_frac = step_frac
        self.cooldown_s = cooldown_s
        self.settle_reads = settle_reads
        self._last_action_t = float("-inf")
        self._low_reads = 0

    def _step_n(self, serving: int) -> int:
        return max(1, int(serving * self.step_frac))

    def step(self, load: FleetLoad) -> List[Action]:
        busy = (load.backlog_s > self.target_wait_s
                or load.hot_frac > self.hot_headroom)
        idle = (load.backlog_s < self.idle_wait_s
                and load.util_mean < self.idle_util
                and load.queue_depth == 0)
        self._low_reads = self._low_reads + 1 if idle else 0
        if load.sim_t - self._last_action_t < self.cooldown_s:
            return []
        provisioned = load.serving + load.warming
        if busy:
            n = min(self._step_n(provisioned), load.spare,
                    self.max_workers - provisioned)
            if n > 0:
                self._last_action_t = load.sim_t
                self._low_reads = 0
                return [Action("scale_up", "", {
                    "n": n, "backlog_s": load.backlog_s,
                    "hot_frac": load.hot_frac})]
            return []
        if idle and self._low_reads >= self.settle_reads:
            n = min(self._step_n(provisioned),
                    load.serving - self.min_workers)
            if n > 0:
                self._last_action_t = load.sim_t
                self._low_reads = 0
                return [Action("scale_down", "", {
                    "n": n, "backlog_s": load.backlog_s,
                    "util_mean": load.util_mean})]
        return []
