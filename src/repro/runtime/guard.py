"""Runtime invariant guards: retrace counting and seeded-replay checks.

The static side of repro-lint (R001-R006) proves the *source* can't
recreate the repo's known bug classes; this module proves the *running
program* doesn't either:

* :class:`TraceGuard` hooks JAX's compilation logging and counts every
  trace/compile inside a ``with`` block.  Wrapped around steady-state
  serving (after warmup), ``max_retraces=0`` turns PR 4's silent
  per-worker recompiles into a hard failure with the offending program
  names in the message.

* :func:`seeded_replay_check` runs a seeded simulation twice and diffs
  the snapshots field-by-field (NaN-aware).  Any divergence means hidden
  wall-clock or global-RNG state leaked into a sim path — the runtime
  face of R002/R003.

``TraceGuard`` imports jax lazily; ``seeded_replay_check`` needs neither
jax nor numpy unless the snapshots contain arrays, so the jax-free scale
plane (``serving/scale.py``) can use it too.
"""

from __future__ import annotations

import dataclasses
import logging
import math
from typing import Any, Callable, List, Optional, Tuple

__all__ = ["TraceGuard", "RetraceError", "DeterminismError",
           "seeded_replay_check", "diff_snapshots"]


# ---------------------------------------------------------------------------
# TraceGuard
# ---------------------------------------------------------------------------


class RetraceError(AssertionError):
    """Raised when a TraceGuard block traced/compiled more than allowed."""


#: loggers that carry compile activity across the jax versions CI runs
#: (dispatch logs "Finished tracing + transforming <name> ...", pxla logs
#: "Compiling <name> with global shapes and types ...").
_JAX_COMPILE_LOGGERS = (
    "jax._src.dispatch",
    "jax._src.interpreters.pxla",
    "jax._src.pjit",
)


class _CompileLogHandler(logging.Handler):
    def __init__(self) -> None:
        super().__init__(level=logging.DEBUG)
        self.traces: List[str] = []
        self.compiles: List[str] = []

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        if "Finished tracing + transforming" in msg:
            self.traces.append(msg)
        elif msg.startswith("Compiling "):
            self.compiles.append(msg)


class TraceGuard:
    """Context manager asserting a bound on jax traces/compiles inside it.

    Usage::

        run()                                # warmup: compile everything
        with TraceGuard(max_retraces=0) as tg:
            run()                            # steady state: must all hit
        assert tg.total == 0

    On exit the guard restores ``jax_log_compiles`` and detaches its log
    handlers; with ``max_retraces=None`` it only observes (read
    ``tg.total`` / ``tg.events`` afterwards).  Retraces are counted as
    trace *or* compile log events — a cache hit emits neither.
    """

    def __init__(self, max_retraces: Optional[int] = 0,
                 name: str = "steady-state") -> None:
        self.max_retraces = max_retraces
        self.name = name
        self._handler = _CompileLogHandler()
        self._prev_flag: Optional[bool] = None
        self._loggers: List[logging.Logger] = []

    # -- results -------------------------------------------------------

    @property
    def traces(self) -> int:
        return len(self._handler.traces)

    @property
    def compiles(self) -> int:
        return len(self._handler.compiles)

    @property
    def total(self) -> int:
        """Retrace events observed (traces + compiles)."""
        return self.traces + self.compiles

    @property
    def events(self) -> List[str]:
        return list(self._handler.traces) + list(self._handler.compiles)

    # -- context -------------------------------------------------------

    def __enter__(self) -> "TraceGuard":
        import jax
        self._prev_flag = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        for name in _JAX_COMPILE_LOGGERS:
            logger = logging.getLogger(name)
            logger.addHandler(self._handler)
            self._loggers.append(logger)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        import jax
        for logger in self._loggers:
            logger.removeHandler(self._handler)
        self._loggers.clear()
        jax.config.update("jax_log_compiles", bool(self._prev_flag))
        if exc_type is not None:
            return  # don't mask the block's own failure
        self.check()

    def check(self) -> None:
        """Raise :class:`RetraceError` if the budget was exceeded."""
        if self.max_retraces is None or self.total <= self.max_retraces:
            return
        head = "; ".join(self.events[:5])
        more = f" (+{len(self.events) - 5} more)" if len(self.events) > 5 else ""
        raise RetraceError(
            f"TraceGuard[{self.name}]: {self.total} trace/compile event(s) "
            f"observed, budget {self.max_retraces}. A warm serving path "
            "must reuse shared jit wrappers (repro-lint R001); new traces "
            f"here mean a recompile per worker/step. Events: {head}{more}")


# ---------------------------------------------------------------------------
# seeded replay determinism
# ---------------------------------------------------------------------------


class DeterminismError(AssertionError):
    """Raised when two identically-seeded runs produced different results."""


def _is_nan(x: Any) -> bool:
    return isinstance(x, float) and math.isnan(x)


def diff_snapshots(a: Any, b: Any, path: str = "",
                   out: Optional[List[str]] = None,
                   limit: int = 20) -> List[str]:
    """Recursive NaN-aware structural diff; returns dotted paths that
    differ (empty list == identical)."""
    out = out if out is not None else []
    if len(out) >= limit:
        return out
    where = path or "<root>"
    if dataclasses.is_dataclass(a) and dataclasses.is_dataclass(b):
        if type(a) is not type(b):
            out.append(f"{where}: {type(a).__name__} != {type(b).__name__}")
            return out
        for f in dataclasses.fields(a):
            diff_snapshots(getattr(a, f.name), getattr(b, f.name),
                           f"{path}.{f.name}" if path else f.name, out, limit)
        return out
    if isinstance(a, dict) and isinstance(b, dict):
        if set(a) != set(b):
            out.append(f"{where}: keys {sorted(set(a) ^ set(b))!r} differ")
            return out
        for k in a:
            diff_snapshots(a[k], b[k], f"{path}[{k!r}]", out, limit)
        return out
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        if len(a) != len(b):
            out.append(f"{where}: length {len(a)} != {len(b)}")
            return out
        for i, (x, y) in enumerate(zip(a, b)):
            diff_snapshots(x, y, f"{path}[{i}]", out, limit)
        return out
    if _is_nan(a) and _is_nan(b):
        return out
    if hasattr(a, "shape") and hasattr(a, "dtype"):  # ndarray-likes
        try:
            import numpy as np
            if not (hasattr(b, "shape") and a.shape == b.shape
                    and np.array_equal(np.asarray(a), np.asarray(b),
                                       equal_nan=True)):
                out.append(f"{where}: arrays differ")
        except Exception:
            out.append(f"{where}: unorderable array-likes")
        return out
    if a != b:
        out.append(f"{where}: {a!r} != {b!r}")
    return out


def seeded_replay_check(fn: Callable[[int], Any], seed: int = 0, *,
                        runs: int = 2,
                        strict: bool = True) -> Tuple[bool, List[str]]:
    """Run ``fn(seed)`` ``runs`` times and diff the returned snapshots.

    ``fn`` must build its ENTIRE simulation from the seed — any hidden
    wall-clock read or process-global RNG shows up as a diff.  Returns
    ``(ok, diffs)``; with ``strict=True`` (default) raises
    :class:`DeterminismError` on divergence instead.
    """
    if runs < 2:
        raise ValueError("seeded_replay_check needs at least 2 runs")
    snaps = [fn(seed) for _ in range(runs)]
    diffs: List[str] = []
    for i, later in enumerate(snaps[1:], start=2):
        for d in diff_snapshots(snaps[0], later):
            diffs.append(f"run1 vs run{i}: {d}")
    ok = not diffs
    if not ok and strict:
        shown = "\n  ".join(diffs[:20])
        raise DeterminismError(
            f"seeded replay diverged for seed={seed} "
            f"({len(diffs)} difference(s)):\n  {shown}\n"
            "A seeded sim must be a pure function of its seed — hidden "
            "wall-clock reads or global RNG state violate repro-lint "
            "R002/R003's runtime contract.")
    return ok, diffs
