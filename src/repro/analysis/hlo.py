"""Collective-traffic extraction from compiled HLO text.

``compiled.cost_analysis()`` has no collective term, so we parse the
(post-SPMD, per-device) HLO: every ``all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute`` op contributes wire bytes
computed from its RESULT shape, its replica-group size n, and the standard
ring-transfer factors:

    all-reduce        2(n-1)/n × bytes(result)
    all-gather         (n-1)/n × bytes(result)           (result = gathered)
    reduce-scatter     (n-1)   × bytes(result)           (result = shard)
    all-to-all         (n-1)/n × bytes(result)
    collective-permute          bytes(result)

Async pairs (``-start``/``-done``) are counted once (on start).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<async>-start|-done)?"
    r"\(", re.MULTILINE)

_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{\{")


def _shape_elems(shape: str) -> List[float]:
    """byte sizes of each array in 'f32[4,8]{1,0}' / '(f32[4], bf16[2,2])'."""
    out = []
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", shape):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dt])
    return out


def _shape_bytes(shape: str) -> float:
    return float(sum(_shape_elems(shape)))


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:                                  # [groups, group_size] iota form
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    ops: List[dict]

    @property
    def wire_bytes(self) -> float:
        return sum(o["wire_bytes"] for o in self.ops)

    @property
    def payload_bytes(self) -> float:
        return sum(o["bytes"] for o in self.ops)

    def by_kind(self) -> Dict[str, Tuple[int, float]]:
        out: Dict[str, Tuple[int, float]] = {}
        for o in self.ops:
            c, b = out.get(o["op"], (0, 0.0))
            out[o["op"]] = (c + 1, b + o["wire_bytes"])
        return out


def parse_collectives(hlo_text: str, n_devices: int,
                      loop_trip_counts: bool = True) -> CollectiveStats:
    """Static per-device collective inventory.

    Note: ops inside while-loop bodies appear ONCE in HLO; the caller scales
    by trip count via cost_analysis cross-check or accepts the static count
    (we report both static and flops-consistent estimates in the roofline).
    """
    ops = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if m.group("async") == "-done":
            continue
        op = m.group("op")
        elems = _shape_elems(m.group("shape"))
        if m.group("async") == "-start" and len(elems) > 1:
            # async-start results are (operand, result[, scratch]) tuples:
            # the RESULT is the largest element
            nbytes = float(max(elems))
        else:
            nbytes = float(sum(elems))
        n = _group_size(line, n_devices)
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * nbytes
        elif op == "all-gather":
            wire = (n - 1) / n * nbytes
        elif op == "reduce-scatter":
            wire = (n - 1) * nbytes
        elif op == "all-to-all":
            wire = (n - 1) / n * nbytes
        else:  # collective-permute
            wire = nbytes
        ops.append({"op": op, "bytes": nbytes, "wire_bytes": wire, "group": n,
                    "line": line.strip()[:160]})
    return CollectiveStats(ops)


def count_op(hlo_text: str, name: str) -> int:
    return len(re.findall(rf"\b{name}\b", hlo_text))
