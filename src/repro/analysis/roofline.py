"""Three-term roofline from dry-run artifacts (DESIGN §9).

    compute    = FLOPs_dev / peak_flops
    memory     = HBM_bytes_dev / hbm_bw
    collective = wire_bytes_dev / (link_bw × efficiency)

Sources: ``cost_analysis()`` flops / bytes-accessed are PER-DEVICE and count
scan bodies ONCE (measured: probe in EXPERIMENTS.md §Method).  Cells lowered
with ``unroll_layers=True`` are exact; scan-mode cells are scaled by the
step-builder's ``layers_multiplier × step multiplier`` — exact for the layer
-loop body, a documented over-count (<~5%) for the out-of-loop epilogue.
``MODEL_FLOPS = 6·N_active·D`` gives the useful-work ratio (remat/dispatch/
attention overheads push HLO flops above it; >1 ratios of HLO/model are
expected for training with remat).
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config
from repro.hw.specs import ICI_EFFICIENCY, TPU_V5E


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    strategy: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    hlo_flops_global: float = 0.0
    useful_ratio: float = 0.0
    peak_gb: float = 0.0
    step_s: float = 0.0            # max of the three (no-overlap bound)
    note: str = ""

    def fraction_of_roofline(self) -> float:
        """compute_term / step_time — how close the cell is to being
        compute-bound at peak (1.0 = perfectly compute-roofed)."""
        return self.compute_s / self.step_s if self.step_s else 0.0


def _multiplier(meta: dict, unrolled: bool) -> float:
    if unrolled:
        m = meta.get("accum_multiplier", 1) or 1
        return float(m)
    m = float(meta.get("layers_multiplier", 1) or 1)
    m *= float(meta.get("accum_multiplier", 1) or 1)
    m *= float(meta.get("tick_multiplier", 1) or 1) if "tick_multiplier" in meta else 1.0
    return m


def row_from_cell(cell: dict) -> RooflineRow:
    row = RooflineRow(arch=cell["arch"], shape=cell["shape"],
                      mesh=cell["mesh"], strategy=cell.get("strategy", ""),
                      status=cell["status"])
    if cell["status"] == "skip":
        row.note = cell.get("reason", "")[:80]
        return row
    if cell["status"] != "ok":
        row.note = cell.get("error", "")[:80]
        return row
    meta = cell.get("meta", {})
    unrolled = cell.get("unrolled", False)
    mult = _multiplier(meta, unrolled)
    chips = 512 if cell["mesh"] == "pod2x16x16" else 256

    hlo_flops_dev = cell["cost"]["flops_per_device"] * mult
    bytes_dev = cell["cost"]["bytes_accessed_per_device"] * mult
    wire_mult = float(meta["wire_multiplier"]) if "wire_multiplier" in meta \
        else mult
    wire_dev = cell["collectives"]["wire_bytes_per_device"] * wire_mult

    cfg = get_config(cell["arch"])
    shape = SHAPES[cell["shape"]]
    # COMPUTE term: analytic model (exact matmul accounting; scan-mode HLO
    # multipliers over-count loop epilogues — see module docstring).
    from repro.analysis.analytic import flops_per_device, step_flops
    pad = int(meta.get("n_pad_layers", 0) or 0)
    flops_dev = flops_per_device(cfg, shape, chips,
                                 remat=shape.kind == "train", pad_layers=pad)

    row.compute_s = flops_dev / TPU_V5E.flops
    row.memory_s = bytes_dev / TPU_V5E.mem_bw
    row.collective_s = wire_dev / (TPU_V5E.link_bw * ICI_EFFICIENCY)
    terms = {"compute": row.compute_s, "memory": row.memory_s,
             "collective": row.collective_s}
    row.dominant = max(terms, key=terms.get)
    row.step_s = max(terms.values())
    row.peak_gb = cell["memory"]["peak_bytes_per_device"] / 1e9

    n = cfg.active_params()
    row.model_flops = (6.0 if shape.kind == "train" else 2.0) * n \
        * shape.tokens_per_step
    # useful-work ratio: 6ND over the ANALYTIC total (attention/remat/CE
    # overheads push it below 1); hlo column kept for cross-check
    row.hlo_flops_global = hlo_flops_dev * chips
    row.useful_ratio = row.model_flops / (flops_dev * chips)
    return row


def improvement_hint(row: RooflineRow) -> str:
    if row.status != "ok":
        return ""
    if row.dominant == "collective":
        return ("shard activations along seq (reduce-scatter/all-gather "
                "instead of per-layer all-reduce) or move DP traffic off the "
                "critical path (overlap / compress)")
    if row.dominant == "memory":
        if row.shape in ("decode_32k", "long_500k"):
            return ("KV-cache reads dominate: shrink cache dtype (int8 KV), "
                    "raise batch per chip, or flash-decode with wider tiles")
        return ("cut activation traffic: fuse norms/elementwise (Pallas), "
                "lower remat scope, bf16 stash")
    return ("increase per-chip arithmetic intensity: larger microbatch, "
            "fewer pipeline bubbles, avoid remat recompute where HBM allows")


def load_cells(art_dir: Path) -> List[dict]:
    return [json.loads(p.read_text()) for p in sorted(art_dir.glob("*.json"))]


def best_rows(cells: List[dict]) -> Dict[tuple, RooflineRow]:
    """One row per (arch, shape, mesh): prefer ok cells, prefer the
    strategy recorded latest (pp/gspmd_pp beat the tp baseline when both
    exist — they are the per-cell default strategies)."""
    out: Dict[tuple, RooflineRow] = {}
    pref = {"pp_shardmap": 2, "gspmd_pp": 2, "gspmd_tp": 1, "": 0}
    for cell in cells:
        row = row_from_cell(cell)
        key = (row.arch, row.shape, row.mesh)
        cur = out.get(key)
        if cur is None:
            out[key] = row
            continue
        if (row.status == "ok", pref.get(row.strategy, 0)) > \
           (cur.status == "ok", pref.get(cur.strategy, 0)):
            out[key] = row
    return out
