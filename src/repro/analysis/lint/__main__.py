"""``python -m repro.analysis.lint`` entry point."""

from repro.analysis.lint.cli import main

if __name__ == "__main__":
    main()
