"""repro-lint: the repo's invariant checker (rules R001-R006).

Every rule encodes a bug class this repo actually shipped and fixed; the
linter keeps the fix from regressing by machine-checking the invariant
instead of trusting reviewer folklore.  See ``docs/INVARIANTS.md`` for the
catalogue (rule -> originating PR -> approved pattern) and
:mod:`repro.runtime.guard` for the runtime-side guards (retrace counting,
seeded replay determinism).

Pure stdlib on purpose: the CLI (``python -m repro.analysis.lint``) must
run on CI's fast tier without jax, numpy, or an installed package —
``PYTHONPATH=src`` and a checkout are enough.
"""

from repro.analysis.lint.core import (FILE_ALLOWLIST, RULES, Violation,
                                      lint_paths, lint_source)
from repro.analysis.lint.rules import (BACKEND_REQUIRED_ATTRS,
                                       ENGINE_REQUIRED_ATTRS,
                                       SIM_CLOCK_SCOPES)

__all__ = [
    "RULES", "Violation", "lint_paths", "lint_source", "FILE_ALLOWLIST",
    "ENGINE_REQUIRED_ATTRS", "BACKEND_REQUIRED_ATTRS", "SIM_CLOCK_SCOPES",
]
