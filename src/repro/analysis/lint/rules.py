"""The six repro-lint rules, each named for the PR whose bug it encodes.

=====  =====================================================================
R001   jit wrappers must be module-level or ``lru_cache``-shared (PR 4:
       per-instance ``jax.jit`` silently recompiled identical programs per
       fleet worker).
R002   no wall-clock in sim-clock modules (PR 7: one ``time.sleep`` in a
       sim path breaks the never-sleep contract; engines pace by
       ``engine.clock``).
R003   PRNG key discipline: a key variable may not feed two ``jax.random``
       consumers without a rebind in between (PR 6: exactly one split per
       emitted token, or spec/plain streams diverge).
R004   no implicit host sync (``.item()``, ``int()/float()/bool()`` on a
       variable, ``np.asarray``) inside ``*step*`` hot-path functions —
       each sync stalls the decode loop for a device roundtrip.
R005   anything calling itself an Engine/Backend must statically define the
       protocol's required attributes (fleet code duck-types against them).
R006   frozen snapshots (EngineSnapshot/FleetSnapshot/ScaleSnapshot/...)
       are immutable outside their defining module — consumers fork with
       ``dataclasses.replace``, never mutate.
=====  =====================================================================

See ``docs/INVARIANTS.md`` for the full catalogue with approved patterns.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set

from repro.analysis.lint.core import (ClassInfo, FileContext, Violation,
                                      rule)

# ---------------------------------------------------------------------------
# Shared configuration (kept here, dependency-free, so CI can lint without
# importing jax; tests pin these against the runtime definitions).
# ---------------------------------------------------------------------------

#: module-path fragments whose code runs under a swappable sim clock.
SIM_CLOCK_SCOPES = (
    "repro/serving/",
    "repro/runtime/elastic.py",
    "repro/runtime/monitor.py",
    "repro/runtime/trainer.py",  # clock= injected (PR 10); fed rounds run on sim time
    "repro/offload/tools.py",  # tool-loop async path; allowlisted for R002
)

#: wall-clock calls banned inside sim-clock scopes.  ``time.perf_counter``
#: is deliberately NOT here: it is the default wall clock engines are
#: constructed with and the telemetry stamp — the ban is on *pacing* by
#: wall time (sleep) and on non-injectable time/randomness sources.
WALL_CLOCK_BANNED = {
    "time.time": "wall-clock read; pace by engine.clock instead",
    "time.sleep": "sim-clock paths must never sleep; advance the SimClock",
    "datetime.datetime.now": "wall-clock read; pace by engine.clock instead",
    "datetime.datetime.utcnow": "wall-clock read; pace by engine.clock instead",
    "datetime.datetime.today": "wall-clock read; pace by engine.clock instead",
    "datetime.date.today": "wall-clock read; pace by engine.clock instead",
}

#: mirror of ``repro.serving.engine_api.REQUIRED_ATTRS`` (pinned by test).
ENGINE_REQUIRED_ATTRS = ("scheduler", "slots", "finished", "max_batch",
                         "metrics")

#: mirror of ``repro.serving.backends.CacheBackend.REQUIRED_ATTRS``.
BACKEND_REQUIRED_ATTRS = ("name", "n_blocks", "state_version",
                          "snapshot_free")

#: frozen snapshot dataclasses and the modules allowed to touch their guts.
SNAPSHOT_CLASSES = {
    "EngineSnapshot", "FleetSnapshot", "ScaleSnapshot", "WorkerSnapshot",
    "GroupSnapshot", "SpecSnapshot", "SLOReport", "ClassSLOReport",
    "FedRoundSnapshot",
}
SNAPSHOT_METHODS = {"snapshot", "metrics_snapshot"}
SNAPSHOT_DEFINING_MODULES = (
    "repro/serving/metrics.py",
    "repro/serving/fleet.py",
    "repro/serving/scale.py",
    "repro/serving/train_plane.py",
)

#: ``jax.random`` callables that mint keys rather than consume them.
_KEY_CONSTRUCTORS = {"key", "PRNGKey", "wrap_key_data", "key_data", "clone",
                     "key_impl"}

_JIT_NAMES = {"jax.jit", "jax.pjit", "jax.experimental.pjit.pjit"}
_CACHE_DECORATORS = {"functools.lru_cache", "functools.cache", "lru_cache",
                     "cache"}


def _in_scope(ctx: FileContext, scopes) -> bool:
    return any(frag in ctx.module if frag.endswith("/")
               else ctx.module.endswith(frag) for frag in scopes)


def _decorator_dotted(ctx: FileContext, dec: ast.AST) -> Optional[str]:
    return ctx.dotted(dec.func if isinstance(dec, ast.Call) else dec)


def _has_cache_decorator(ctx: FileContext, fn: ast.AST) -> bool:
    decs = getattr(fn, "decorator_list", [])
    return any(_decorator_dotted(ctx, d) in _CACHE_DECORATORS for d in decs)


# ---------------------------------------------------------------------------
# R001 — shared jit wrappers (PR 4)
# ---------------------------------------------------------------------------


@rule("R001", "jit wrappers must be module-level or lru_cache-shared")
def r001_shared_jit(ctx: FileContext) -> Iterator[Violation]:
    for node in ast.walk(ctx.tree):
        what, where = None, node
        if isinstance(node, ast.Call):
            dn = ctx.dotted(node.func)
            if dn in _JIT_NAMES:
                what = f"`{dn}(...)`"
            elif dn == "functools.partial" and node.args and \
                    ctx.dotted(node.args[0]) in _JIT_NAMES:
                what = "`functools.partial(jax.jit, ...)`"
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _decorator_dotted(ctx, dec) in _JIT_NAMES:
                    what = f"`@jax.jit` on `{node.name}`"
                    where = dec  # report (and pragma-match) at the decorator
        if what is None:
            continue
        scopes = ctx.scopes(node)
        if not scopes:
            continue  # module level: the approved pattern
        funcs = [s for s in scopes
                 if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Lambda))]
        in_class = any(isinstance(s, ast.ClassDef) for s in scopes)
        if in_class:
            yield Violation(
                "R001", ctx.path, where.lineno, where.col_offset,
                f"{what} created inside a class scope: per-instance jit "
                "wrappers recompile one program per object (PR 4's fleet "
                "recompile bug). Hoist to module level or an "
                "@functools.lru_cache factory keyed on the config.")
        elif not any(_has_cache_decorator(ctx, f) for f in funcs):
            yield Violation(
                "R001", ctx.path, where.lineno, where.col_offset,
                f"{what} created inside a function without lru_cache "
                "sharing: every call builds a fresh wrapper and retraces. "
                "Hoist to module level or wrap the factory in "
                "@functools.lru_cache.")


# ---------------------------------------------------------------------------
# R002 — never-sleep / no wall clock in sim modules (PR 7)
# ---------------------------------------------------------------------------


@rule("R002", "no wall-clock (time.time/sleep, datetime.now, random) in "
              "sim-clock modules")
def r002_no_wall_clock(ctx: FileContext) -> Iterator[Violation]:
    if not _in_scope(ctx, SIM_CLOCK_SCOPES):
        return
    seen: Set[int] = set()
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.Attribute, ast.Name)):
            continue
        # skip attribute sub-chains so `time.sleep` reports once
        parent = getattr(node, "_repro_parent", None)
        if isinstance(parent, ast.Attribute):
            continue
        dn = ctx.dotted(node)
        if dn is None:
            continue
        why = WALL_CLOCK_BANNED.get(dn)
        if why is None and (dn == "random" or dn.startswith("random.")):
            why = ("stdlib random is process-global and unseedable per "
                   "lane; use a seeded numpy Generator or jax.random key")
        if why is None:
            continue
        if node.lineno in seen:
            continue
        seen.add(node.lineno)
        yield Violation(
            "R002", ctx.path, node.lineno, node.col_offset,
            f"`{dn}` in a sim-clock module: {why} (PR 7's never-sleep "
            "contract; see docs/INVARIANTS.md#r002).")


# ---------------------------------------------------------------------------
# R003 — PRNG key discipline (PR 6)
# ---------------------------------------------------------------------------


def _key_consumers(ctx: FileContext, expr: ast.AST):
    """Yield (call, [Name args]) for jax.random consumers inside expr."""
    for node in ast.walk(expr):
        if not isinstance(node, ast.Call):
            continue
        dn = ctx.dotted(node.func)
        if not dn or not dn.startswith("jax.random."):
            continue
        if dn.rsplit(".", 1)[1] in _KEY_CONSTRUCTORS:
            continue
        # by jax.random convention the key is the first positional arg
        # (or the `key=`/`seed=` kwarg); other args are data, not keys.
        candidates: List[ast.AST] = []
        if node.args:
            candidates.append(node.args[0])
        candidates.extend(kw.value for kw in node.keywords
                          if kw.arg in ("key", "seed", "rng"))
        names = [a for a in candidates if isinstance(a, ast.Name)]
        yield node, dn, names


def _assigned_names(stmt: ast.stmt) -> Set[str]:
    out: Set[str] = set()
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        targets = [stmt.target]
    for tgt in targets:
        if isinstance(tgt, ast.Name):
            out.add(tgt.id)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            out.update(el.id for el in tgt.elts if isinstance(el, ast.Name))
    return out


def _scan_keys(ctx: FileContext, body: List[ast.stmt],
               consumed: Dict[str, int]) -> Iterator[Violation]:
    """Linear scan: a Name consumed twice with no rebind in between fires.

    Branch bodies are scanned with *copies* of the consumed-set and never
    merged back, so cross-branch reuse is not flagged (conservative: no
    false positives from mutually exclusive paths).
    """
    for stmt in body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield from _scan_keys(ctx, stmt.body, {})
            continue
        if isinstance(stmt, ast.ClassDef):
            yield from _scan_keys(ctx, stmt.body, {})
            continue
        # header expressions evaluate in the current state
        if isinstance(stmt, ast.If):
            headers, blocks = [stmt.test], [stmt.body, stmt.orelse]
        elif isinstance(stmt, ast.While):
            headers, blocks = [stmt.test], [stmt.body, stmt.orelse]
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            headers, blocks = [stmt.iter], [stmt.body, stmt.orelse]
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            headers, blocks = [i.context_expr for i in stmt.items], [stmt.body]
        elif isinstance(stmt, ast.Try):
            headers, blocks = [], [stmt.body, stmt.orelse, stmt.finalbody] + \
                [h.body for h in stmt.handlers]
        else:
            headers, blocks = [stmt], []
        for header in headers:
            for call, dn, names in _key_consumers(ctx, header):
                for name in names:
                    prev = consumed.get(name.id)
                    if prev is not None:
                        yield Violation(
                            "R003", ctx.path, call.lineno, call.col_offset,
                            f"PRNG key `{name.id}` passed to `{dn}` but "
                            f"already consumed on line {prev} with no "
                            "rebind in between: reusing a key replays the "
                            "same randomness (PR 6's one-split-per-token "
                            "contract). Rebind first, e.g. "
                            f"`{name.id}, sub = jax.random.split({name.id})`.")
                    else:
                        consumed[name.id] = call.lineno
        if not blocks:
            # rebinds clear consumption AFTER the statement's own uses, so
            # `kk, sub = jax.random.split(kk)` is the approved pattern.
            for name in _assigned_names(stmt):
                consumed.pop(name, None)
        for block in blocks:
            if block:
                yield from _scan_keys(ctx, block, dict(consumed))
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            # loop bodies may rebind; drop anything the body assigns
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.stmt):
                    for name in _assigned_names(sub):
                        consumed.pop(name, None)


@rule("R003", "a jax.random key may not feed two consumers without a rebind")
def r003_key_discipline(ctx: FileContext) -> Iterator[Violation]:
    yield from _scan_keys(ctx, ctx.tree.body, {})


# ---------------------------------------------------------------------------
# R004 — no implicit host sync in hot-path *step* functions (PRs 2/6)
# ---------------------------------------------------------------------------

_CASTS = {"int", "float", "bool"}


@rule("R004", "no implicit host sync (.item(), int()/float()/bool(), "
              "np.asarray) in *step* hot paths")
def r004_no_host_sync(ctx: FileContext) -> Iterator[Violation]:
    if not ctx.imports_jax:
        return  # jax-free modules have no device arrays to sync
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if "step" not in fn.name.lower():
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            # x.item()
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                yield Violation(
                    "R004", ctx.path, node.lineno, node.col_offset,
                    f"`.item()` inside hot-path `{fn.name}`: each call "
                    "blocks on a device->host roundtrip per token. Batch "
                    "the transfer (one np.asarray per step outside the "
                    "lane loop) or keep the value on device.")
                continue
            dn = ctx.dotted(node.func)
            if dn in ("numpy.asarray", "numpy.array"):
                # building an array FROM host literals is not a sync
                if node.args and isinstance(
                        node.args[0], (ast.List, ast.Tuple, ast.Dict,
                                       ast.ListComp, ast.GeneratorExp,
                                       ast.Constant)):
                    continue
                yield Violation(
                    "R004", ctx.path, node.lineno, node.col_offset,
                    f"`np.asarray` inside hot-path `{fn.name}`: implicit "
                    "device sync. Hoist the single allowed sync out of "
                    "the per-lane loop, or mark the deliberate sync "
                    "point with a pragma.")
                continue
            if (isinstance(node.func, ast.Name)
                    and node.func.id in _CASTS
                    and node.func.id not in ctx.aliases
                    and len(node.args) == 1
                    and isinstance(node.args[0],
                                   (ast.Name, ast.Attribute, ast.Subscript))):
                yield Violation(
                    "R004", ctx.path, node.lineno, node.col_offset,
                    f"`{node.func.id}(...)` on a variable inside hot-path "
                    f"`{fn.name}`: casting a device array is an implicit "
                    "host sync per element. Use `.tolist()` once per "
                    "step, or pragma the deliberate sync point.")


# ---------------------------------------------------------------------------
# R005 — Engine/Backend classes must define the protocol attrs (PRs 3/6)
# ---------------------------------------------------------------------------


def _resolved_attrs(index: Dict[str, ClassInfo], name: str,
                    seen: Optional[Set[str]] = None):
    """(attrs, fully_resolved) walking the base chain through the index."""
    seen = seen or set()
    if name in seen:
        return set(), True
    seen.add(name)
    info = index.get(name)
    if info is None:
        return set(), name == "object"
    attrs = set(info.attrs)
    resolved = True
    for base in info.bases:
        if base in ("object", "Protocol", "Generic", "ABC"):
            continue
        sub, ok = _resolved_attrs(index, base, seen)
        attrs |= sub
        resolved = resolved and ok
    return attrs, resolved


@rule("R005", "Engine/Backend classes must statically define the "
              "protocol's REQUIRED_ATTRS")
def r005_protocol_attrs(ctx: FileContext) -> Iterator[Violation]:
    index: Dict[str, ClassInfo] = getattr(ctx, "index", {})
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        info = index.get(node.name)
        if info is None or info.is_protocol:
            continue
        claims = None
        if node.name.endswith("Engine"):
            claims, required = "DecodeEngine", ENGINE_REQUIRED_ATTRS
        elif node.name.endswith("Backend"):
            claims, required = "CacheBackend", BACKEND_REQUIRED_ATTRS
        if claims is None:
            continue
        attrs, resolved = _resolved_attrs(index, node.name)
        if not resolved:
            continue  # opaque external base: cannot prove either way
        missing = [a for a in required if a not in attrs]
        if missing:
            yield Violation(
                "R005", ctx.path, node.lineno, node.col_offset,
                f"class `{node.name}` claims the {claims} protocol but "
                f"never defines {missing}: fleet code duck-types against "
                f"REQUIRED_ATTRS and will fail at routing time, not "
                "construction time. Define them in __init__ or at class "
                "level.")


# ---------------------------------------------------------------------------
# R006 — frozen snapshots are immutable outside their defining module
# ---------------------------------------------------------------------------


def _snapshot_sources(ctx: FileContext, value: ast.AST) -> bool:
    """True if `value` constructs a snapshot or calls a .snapshot() method."""
    if not isinstance(value, ast.Call):
        return False
    fn = value.func
    if isinstance(fn, ast.Name) and fn.id in SNAPSHOT_CLASSES:
        return True
    if isinstance(fn, ast.Attribute):
        if fn.attr in SNAPSHOT_CLASSES or fn.attr in SNAPSHOT_METHODS:
            return True
    return False


@rule("R006", "frozen snapshot dataclasses are immutable outside their "
              "defining module")
def r006_snapshot_immutable(ctx: FileContext) -> Iterator[Violation]:
    if any(ctx.module.endswith(m) for m in SNAPSHOT_DEFINING_MODULES):
        return
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Module)):
            continue
        body = fn.body if not isinstance(fn, ast.Module) else [
            s for s in fn.body
            if not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef))]
        tracked: Set[str] = set()
        for stmt in body if isinstance(fn, ast.Module) else ast.walk(fn):
            if isinstance(stmt, ast.Assign) and _snapshot_sources(
                    ctx, stmt.value):
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name):
                        tracked.add(tgt.id)
            if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                for tgt in targets:
                    if not isinstance(tgt, ast.Attribute):
                        continue
                    hit = (isinstance(tgt.value, ast.Name)
                           and tgt.value.id in tracked) or \
                        _snapshot_sources(ctx, tgt.value)
                    if hit:
                        yield Violation(
                            "R006", ctx.path, stmt.lineno, stmt.col_offset,
                            f"mutating snapshot field `.{tgt.attr}`: "
                            "snapshots are frozen telemetry records shared "
                            "across consumers; fork with "
                            "`dataclasses.replace(snap, ...)` instead.")
            # only the Expr wrapper, not the Call it contains: ast.walk
            # visits both and matching either would double-report
            call = stmt.value if (isinstance(stmt, ast.Expr)
                                  and isinstance(stmt.value, ast.Call)) \
                else None
            if call is not None:
                dn = ctx.dotted(call.func)
                if dn == "object.__setattr__" and call.args and \
                        isinstance(call.args[0], ast.Name) and \
                        call.args[0].id in tracked:
                    yield Violation(
                        "R006", ctx.path, stmt.lineno, stmt.col_offset,
                        "`object.__setattr__` on a frozen snapshot "
                        "outside its defining module: fork with "
                        "`dataclasses.replace` instead.")
