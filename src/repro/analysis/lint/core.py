"""Core machinery for repro-lint: contexts, pragmas, and the file walker.

Pure stdlib (``ast`` + ``re`` + ``pathlib``) so the linter can run on CI
runners that never install jax.  Rules live in
:mod:`repro.analysis.lint.rules` and register themselves via
:func:`rule`; this module only knows how to parse files, resolve import
aliases, and apply suppressions.

Suppression has exactly two mechanisms, both of which require a reason:

* An inline pragma on the flagged line (or the line above)::

      time.sleep(wait)  # repro-lint: allow[R002] wall-clock engines nap for real

  A pragma without a reason does **not** suppress — the violation is
  reported with a note saying so.  This keeps every exemption auditable.

* A module-level entry in :data:`FILE_ALLOWLIST`, keyed by
  ``(posix-suffix, rule-id)``, for files whose entire purpose violates a
  rule (e.g. the async tool executor sleeps simulated seconds by design).
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Violations and rule registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Violation:
    """One rule breach at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


#: rule id -> (one-line title, check function).  Populated by :func:`rule`.
RULES: Dict[str, Tuple[str, Callable[["FileContext"], Iterator[Violation]]]] = {}


def rule(rule_id: str, title: str):
    """Decorator registering a check function under ``rule_id``."""

    def register(fn):
        RULES[rule_id] = (title, fn)
        return fn

    return register


#: whole-file exemptions: (path suffix, rule id) -> reason.  The suffix is
#: matched against the file's posix path, so entries stay stable across
#: checkout locations.  Every entry must explain itself; the CLI prints the
#: allowlist so exemptions stay visible.
FILE_ALLOWLIST: Dict[Tuple[str, str], str] = {
    ("repro/offload/tools.py", "R002"): (
        "the async tool executor models tool latency with REAL sleeping "
        "threads so the engine's decode/tool overlap is measured, not "
        "simulated; this is the tool-loop wall path, and it never runs "
        "under a SimClock"
    ),
}

# ``# repro-lint: allow[R001] reason`` / ``allow[R001,R004] reason``
_PRAGMA_RE = re.compile(
    r"#\s*repro-lint:\s*allow\[([A-Z0-9,\s]+)\]\s*(.*?)\s*$"
)


# ---------------------------------------------------------------------------
# Per-file context
# ---------------------------------------------------------------------------


class FileContext:
    """Parsed source plus the lookup helpers every rule needs."""

    def __init__(self, source: str, path: str = "<memory>",
                 module: Optional[str] = None) -> None:
        self.source = source
        self.path = path
        #: posix-style path used for scope/allowlist matching; callers pass
        #: the repo-relative path, fixtures can fake one (e.g.
        #: ``repro/serving/fake.py``) to land inside a rule's scope.
        self.module = (module or path).replace("\\", "/")
        self.lines = source.splitlines()
        self.tree = ast.parse(source)
        self._link_parents()
        self.aliases: Dict[str, str] = {}
        self._collect_imports()
        #: pre-parsed pragmas: line -> (set of rule ids, reason)
        self.pragmas: Dict[int, Tuple[set, str]] = {}
        self._collect_pragmas()

    # -- structure -----------------------------------------------------

    def _link_parents(self) -> None:
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                child._repro_parent = parent  # type: ignore[attr-defined]

    def scopes(self, node: ast.AST) -> List[ast.AST]:
        """Enclosing function/class scopes, outermost first, excluding node."""
        chain: List[ast.AST] = []
        cur = getattr(node, "_repro_parent", None)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda, ast.ClassDef)):
                chain.append(cur)
            cur = getattr(cur, "_repro_parent", None)
        chain.reverse()
        return chain

    def enclosing_functions(self, node: ast.AST) -> List[ast.AST]:
        return [s for s in self.scopes(node)
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda))]

    # -- imports -------------------------------------------------------

    def _collect_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    name = alias.asname or alias.name.split(".")[0]
                    # ``import a.b`` binds ``a``; ``import a.b as c`` binds c->a.b
                    target = alias.name if alias.asname else alias.name.split(".")[0]
                    self.aliases[name] = target
            elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    bound = alias.asname or alias.name
                    self.aliases[bound] = f"{node.module}.{alias.name}"

    @property
    def imports_jax(self) -> bool:
        return any(tgt == "jax" or tgt.startswith("jax.")
                   for tgt in self.aliases.values())

    def dotted(self, node: ast.AST) -> Optional[str]:
        """Resolve a Name/Attribute chain to a dotted module path.

        ``np.asarray`` -> ``numpy.asarray`` when the file did
        ``import numpy as np``; ``sleep`` -> ``time.sleep`` after
        ``from time import sleep``.  Returns None for anything that is not
        a plain chain rooted at a known alias or bare name.
        """
        parts: List[str] = []
        cur = node
        while isinstance(cur, ast.Attribute):
            parts.append(cur.attr)
            cur = cur.value
        if not isinstance(cur, ast.Name):
            return None
        root = self.aliases.get(cur.id, cur.id)
        parts.append(root)
        parts.reverse()
        return ".".join(parts)

    # -- pragmas -------------------------------------------------------

    def _collect_pragmas(self) -> None:
        for lineno, text in enumerate(self.lines, start=1):
            m = _PRAGMA_RE.search(text)
            if m is None:
                continue
            ids = {p.strip() for p in m.group(1).split(",") if p.strip()}
            self.pragmas[lineno] = (ids, m.group(2).strip())

    def suppressed(self, rule_id: str, line: int) -> Optional[bool]:
        """None = no pragma; True = valid suppression; False = reasonless."""
        for lineno in (line, line - 1):
            entry = self.pragmas.get(lineno)
            if entry and rule_id in entry[0]:
                return bool(entry[1])
        return None

    def allowlisted(self, rule_id: str) -> bool:
        return any(self.module.endswith(suffix) and rid == rule_id
                   for (suffix, rid) in FILE_ALLOWLIST)


# ---------------------------------------------------------------------------
# Project index (cross-file class table for R005)
# ---------------------------------------------------------------------------


@dataclass
class ClassInfo:
    name: str
    module: str
    bases: List[str] = field(default_factory=list)
    attrs: set = field(default_factory=set)
    is_protocol: bool = False


def _class_attrs(node: ast.ClassDef) -> set:
    """Names statically assigned at class level or as ``self.X`` in methods."""
    attrs: set = set()
    for stmt in node.body:
        if isinstance(stmt, ast.Assign):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name):
                    attrs.add(tgt.id)
        elif isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            attrs.add(stmt.target.id)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            attrs.add(stmt.name)
            for sub in ast.walk(stmt):
                tgts: List[ast.AST] = []
                if isinstance(sub, ast.Assign):
                    tgts = list(sub.targets)
                elif isinstance(sub, (ast.AnnAssign, ast.AugAssign)):
                    tgts = [sub.target]
                for tgt in tgts:
                    if (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        attrs.add(tgt.attr)
                    elif isinstance(tgt, ast.Tuple):
                        for el in tgt.elts:
                            if (isinstance(el, ast.Attribute)
                                    and isinstance(el.value, ast.Name)
                                    and el.value.id == "self"):
                                attrs.add(el.attr)
    return attrs


def _base_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Subscript):  # Protocol[...] / Generic[...]
        return _base_name(node.value)
    return None


def build_index(contexts: Iterable[FileContext]) -> Dict[str, ClassInfo]:
    """Cross-file class table so R005 can resolve inherited attributes."""
    index: Dict[str, ClassInfo] = {}
    for ctx in contexts:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            bases = [b for b in (_base_name(n) for n in node.bases) if b]
            index[node.name] = ClassInfo(
                name=node.name,
                module=ctx.module,
                bases=bases,
                attrs=_class_attrs(node),
                is_protocol="Protocol" in bases,
            )
    return index


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def _apply(ctx: FileContext,
           rule_ids: Iterable[str]) -> List[Violation]:
    out: List[Violation] = []
    for rid in rule_ids:
        title, fn = RULES[rid]
        if ctx.allowlisted(rid):
            continue
        for v in fn(ctx):
            sup = ctx.suppressed(v.rule, v.line)
            if sup is True:
                continue
            if sup is False:
                v = Violation(v.rule, v.path, v.line, v.col,
                              v.message + " (pragma present but missing a "
                              "reason; suppressions must explain themselves)")
            out.append(v)
    return out


def lint_source(source: str, path: str = "<fixture>",
                module: Optional[str] = None,
                rules: Optional[Iterable[str]] = None,
                index: Optional[Dict[str, ClassInfo]] = None) -> List[Violation]:
    """Lint a source string (fixture entry point for tests)."""
    _ensure_rules()
    ctx = FileContext(source, path=path, module=module)
    ctx.index = index if index is not None else build_index([ctx])  # type: ignore[attr-defined]
    return _apply(ctx, rules or sorted(RULES))


def iter_py_files(root: Path) -> Iterator[Path]:
    for p in sorted(root.rglob("*.py")):
        if "__pycache__" in p.parts:
            continue
        yield p


def lint_paths(paths: Iterable[Path],
               rules: Optional[Iterable[str]] = None) -> List[Violation]:
    """Lint files/trees; returns violations sorted by path/line."""
    _ensure_rules()
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        files.extend(iter_py_files(p) if p.is_dir() else [p])
    contexts: List[FileContext] = []
    errors: List[Violation] = []
    for f in files:
        rel = f.as_posix()
        try:
            contexts.append(FileContext(f.read_text(), path=str(f), module=rel))
        except SyntaxError as e:  # a file the linter can't parse is a finding
            errors.append(Violation("R000", str(f), e.lineno or 0, 0,
                                    f"unparseable source: {e.msg}"))
    index = build_index(contexts)
    out = list(errors)
    for ctx in contexts:
        ctx.index = index  # type: ignore[attr-defined]
        out.extend(_apply(ctx, rules or sorted(RULES)))
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


def _ensure_rules() -> None:
    if not RULES:
        from repro.analysis.lint import rules as _rules  # noqa: F401
