"""CLI for repro-lint: ``python -m repro.analysis.lint [paths] [--strict]``.

Prints a per-rule summary table, the violation list, and (on GitHub
Actions) appends the same table to ``$GITHUB_STEP_SUMMARY`` so the CI job
page shows which invariant broke without digging through logs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis.lint.core import (FILE_ALLOWLIST, RULES, Violation,
                                      lint_paths)
from repro.analysis.lint import rules as _rules  # noqa: F401  (registers)

#: default scan root: the ``src/`` tree this package lives in.
DEFAULT_ROOT = Path(__file__).resolve().parents[3]


def _rule_table(violations: List[Violation]) -> List[tuple]:
    counts = {rid: 0 for rid in sorted(RULES)}
    for v in violations:
        counts[v.rule] = counts.get(v.rule, 0) + 1
    return [(rid, RULES[rid][0] if rid in RULES else "(parse error)", n)
            for rid, n in sorted(counts.items())]


def _markdown_summary(violations: List[Violation], n_files: int) -> str:
    lines = ["## repro-lint invariants", "",
             f"Scanned {n_files} file(s); "
             f"**{len(violations)} violation(s)**.", "",
             "| rule | invariant | violations |",
             "| --- | --- | ---: |"]
    for rid, title, n in _rule_table(violations):
        lines.append(f"| {rid} | {title} | {n} |")
    if violations:
        lines += ["", "```"]
        lines += [v.format() for v in violations[:50]]
        if len(violations) > 50:
            lines.append(f"... and {len(violations) - 50} more")
        lines.append("```")
    return "\n".join(lines) + "\n"


def run(argv: Optional[List[str]] = None) -> int:
    """Run the linter; returns the number of violations found."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repro-lint: AST invariant checker (rules R001-R006)")
    ap.add_argument("paths", nargs="*", type=Path,
                    help=f"files or trees to lint (default: {DEFAULT_ROOT})")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on any violation (CI mode)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, (title, _) in sorted(RULES.items()):
            print(f"{rid}  {title}")
        for (suffix, rid), reason in sorted(FILE_ALLOWLIST.items()):
            print(f"allow  {rid} {suffix}: {reason}")
        return 0

    rule_ids = ([r.strip() for r in args.rules.split(",") if r.strip()]
                if args.rules else None)
    if rule_ids:
        unknown = [r for r in rule_ids if r not in RULES]
        if unknown:
            ap.error(f"unknown rule(s): {unknown}; known: {sorted(RULES)}")
    paths = args.paths or [DEFAULT_ROOT]
    violations = lint_paths(paths, rules=rule_ids)
    n_files = sum(1 for p in paths for _ in
                  ([p] if Path(p).is_file() else Path(p).rglob("*.py")))

    if args.format == "json":
        print(json.dumps({
            "files": n_files,
            "violations": [vars(v) for v in violations],
            "by_rule": {rid: n for rid, _, n in _rule_table(violations)},
        }, indent=2))
    else:
        for v in violations:
            print(v.format())
        print(f"repro-lint: {len(violations)} violation(s) in "
              f"{n_files} file(s) "
              f"[{', '.join(f'{rid}:{n}' for rid, _, n in _rule_table(violations))}]")

    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as fh:
            fh.write(_markdown_summary(violations, n_files))
    return len(violations)


def main(argv: Optional[List[str]] = None) -> None:
    args = sys.argv[1:] if argv is None else argv
    n = run(args)
    raise SystemExit(1 if n and "--strict" in args else 0)
