"""Analytic FLOP model per (arch × shape × strategy) — the roofline's
compute term.

Why analytic: XLA cost analysis counts scan bodies once and both branches of
conditionals, so scan-mode HLO numbers need structural multipliers that
over-count loop epilogues (the chunked-CE body is comparable to a layer body
at 256k vocab).  The closed-form model below is exact for the matmul terms
(which are >95% of compute) and is cross-checked against UNROLLED HLO counts
for the hillclimb cells (EXPERIMENTS §Perf: agreement within ~15%).
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import pad_vocab


def _attn_layer_flops(cfg: ModelConfig, tokens: float, seq: int,
                      causal: bool = True) -> float:
    proj = 2.0 * tokens * cfg.attn_params()
    span = min(seq, cfg.chunk_size) if cfg.attention == "chunked_local" else seq
    pair_frac = 0.5 if causal else 1.0
    scores = 4.0 * tokens * span * pair_frac * cfg.n_heads * cfg.head_dim
    return proj + scores


def _mlp_layer_flops(cfg: ModelConfig, tokens: float) -> float:
    if cfg.n_experts:
        mats = 3 if cfg.glu else 2
        active = (cfg.top_k + cfg.n_shared_experts) * mats * cfg.d_model * cfg.d_ff
        router = cfg.d_model * cfg.n_experts
        return 2.0 * tokens * (active + router)
    return 2.0 * tokens * cfg.mlp_params()


def _rwkv_layer_flops(cfg: ModelConfig, tokens: float) -> float:
    k = cfg.d_model // cfg.n_heads
    wkv = 6.0 * tokens * cfg.n_heads * k * k           # out+state+intra
    return 2.0 * tokens * cfg.layer_params() + wkv


def _mamba_layer_flops(cfg: ModelConfig, tokens: float) -> float:
    from repro.models.ssm import dims
    d_in, nheads, _ = dims(cfg)
    c = 64
    ssd = tokens * nheads * (2 * c * cfg.ssm_state + 2 * c * cfg.ssm_headdim
                             + 4 * cfg.ssm_headdim * cfg.ssm_state)
    return 2.0 * tokens * cfg.layer_params() + ssd


def fwd_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global forward FLOPs of one step of this cell."""
    b = shape.global_batch
    if shape.kind == "decode":
        tokens = float(b)
        span = min(shape.seq_len, cfg.chunk_size) \
            if cfg.attention == "chunked_local" else shape.seq_len
        total = 0.0
        if cfg.rwkv:
            total += cfg.n_layers * _rwkv_layer_flops(cfg, tokens)
        elif cfg.family in ("ssm", "hybrid"):
            total += cfg.n_layers * _mamba_layer_flops(cfg, tokens)
            if cfg.attn_every:
                n_attn = -(-cfg.n_layers // cfg.attn_every)
                total += n_attn * (2 * tokens * (cfg.attn_params()
                                                 + cfg.mlp_params())
                                   + 4 * tokens * span * cfg.n_heads
                                   * cfg.head_dim)
        else:
            per = (2 * tokens * cfg.attn_params()
                   + 4 * tokens * span * cfg.n_heads * cfg.head_dim)
            per += _mlp_layer_flops(cfg, tokens)
            total += cfg.n_layers * per
            if cfg.n_enc_layers:            # whisper cross-attn reads
                total += cfg.n_layers * (2 * tokens * cfg.attn_params()
                                         + 4 * tokens * cfg.frontend_seq
                                         * cfg.n_heads * cfg.head_dim)
        total += 2.0 * tokens * cfg.d_model * pad_vocab(cfg.vocab_size)
        return total

    # train / prefill: full sequences
    tokens = float(shape.tokens_per_step)
    seq = shape.seq_len
    total = 0.0
    if cfg.rwkv:
        total = cfg.n_layers * _rwkv_layer_flops(cfg, tokens)
    elif cfg.family in ("ssm", "hybrid"):
        total = cfg.n_layers * _mamba_layer_flops(cfg, tokens)
        if cfg.attn_every:
            n_attn = -(-cfg.n_layers // cfg.attn_every)
            total += n_attn * (_attn_layer_flops(cfg, tokens, seq)
                               + 2 * tokens * cfg.mlp_params())
    elif cfg.n_enc_layers:                  # whisper enc-dec
        enc_tokens = float(b * cfg.frontend_seq)
        total += cfg.n_enc_layers * (
            _attn_layer_flops(cfg, enc_tokens, cfg.frontend_seq, causal=False)
            + 2 * enc_tokens * cfg.mlp_params())
        total += cfg.n_layers * (
            _attn_layer_flops(cfg, tokens, seq)
            + 2 * tokens * cfg.attn_params()                 # cross proj
            + 4 * tokens * cfg.frontend_seq * cfg.n_heads * cfg.head_dim
            + 2 * tokens * cfg.mlp_params())
    else:
        total = cfg.n_layers * (_attn_layer_flops(cfg, tokens, seq)
                                + _mlp_layer_flops(cfg, tokens))
    # head/CE: every position for train, last token for prefill
    ce_tokens = tokens if shape.kind == "train" else float(b)
    total += 2.0 * ce_tokens * cfg.d_model * pad_vocab(cfg.vocab_size)
    return total


def step_flops(cfg: ModelConfig, shape: ShapeConfig, remat: bool = True,
               pad_layers: int = 0) -> float:
    """Global FLOPs of one step (train: fwd+bwd (3x) + remat re-fwd (1x))."""
    f = fwd_flops(cfg, shape)
    if shape.kind == "train":
        f *= 4.0 if remat else 3.0
    if pad_layers:
        f *= 1.0 + pad_layers / cfg.n_layers
    return f


def flops_per_device(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                     remat: bool = True, pad_layers: int = 0) -> float:
    return step_flops(cfg, shape, remat, pad_layers) / chips
